//! Model-based tests for the multi-process shard coordinator.
//!
//! Mirrors `tests/stateful.rs`: random op sequences — SpawnWorker /
//! KillWorker / Rejoin / InjectFault / Update / Step / Retire — drive a
//! [`Coordinator`] over the fault-injecting [`SimTransport`] against an
//! **independent single-process reference model** (`RefModel` below: the
//! same `RoutingSession` + `EpochCache` + `MemberCache` primitives the
//! in-process serve loop composes, executing whole sequences inline with
//! `Backend::attention`).  After every op the suite asserts
//!
//! * attention outputs are **bit-identical** to the reference, no matter
//!   which rows which worker computed (or recomputed after a crash),
//! * every row-range completes **exactly once** —
//!   `worker_rows + inline_rows` equals `n ×` (attention calls), with
//!   late/duplicated replies rejected by task id, never double-written,
//! * the grant ledger conserves: `grants == accepted + superseded +
//!   voided` at rest, and `regrants <= superseded + voided`,
//! * stale-epoch/duplicate rejection counters classify exactly the
//!   replies that arrive with no outstanding grant, and
//! * the coordinator's routing-state counters (compile cache, epoch
//!   cache, membership regeneration, live compiles) evolve identically
//!   to the single-process model — the counter half of the
//!   `--workers N` ≡ `--workers 0` bit-identity contract.
//!
//! Wire-level properties (frame round-trips, `AttentionSpec` /
//! [`AssignmentDelta`] / [`RouteUpdate`] JSON round-trips) ride in the
//! same harness, and one test drives **real** `rtx worker` subprocesses
//! through [`ProcessTransport`] via `CARGO_BIN_EXE_rtx`.
//!
//! Seeds replay from `proptest-regressions/coordinator.txt` (see
//! `tests/common/mod.rs`).

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use routing_transformer::attention::backend;
use routing_transformer::attention::{
    read_frame, routed_family_spec, run_serve, run_serve_coordinated, write_frame, ArrivalConfig,
    AttentionSpec, Backend, CompiledPattern, Coordinator, CoordinatorConfig, EpochCache,
    MemberCache, MemoryBudget, ProcessTransport, RegenStats, RouteSlot, RouteUpdate,
    RoutingSession, ServeOptions, SimTransport, SpecFamily, WorkerId, WorkerState,
};
use routing_transformer::kmeans::AssignmentDelta;
use routing_transformer::util::json::Json;
use routing_transformer::util::rng::Rng;

/// Shrink seeds persisted from previous failures; replayed before the sweep.
const REGRESSIONS: &str = include_str!("../proptest-regressions/coordinator.txt");

/// Run `f` over the recorded regression seeds, then `n` fresh seeded
/// cases; panic with the failing seed (persisting new failures).
fn check<F: Fn(&mut Rng)>(name: &str, n: usize, f: F) {
    common::check_with_regressions("coordinator", REGRESSIONS, name, n, 0xC00D_0000, f);
}

fn vecs(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: value {i} differs ({g} vs {w})");
    }
}

fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> Option<&'a T> {
    if xs.is_empty() {
        None
    } else {
        Some(&xs[rng.below(xs.len())])
    }
}

// ----------------------------------------------- single-process reference

/// The independent reference: the exact routing-state primitives the
/// in-process serve loop composes (`RoutingSession` owning the k-means,
/// `EpochCache` keyed on assignment epochs, one `MemberCache` per
/// `(layer, head, slot)`), executing every attention call inline over
/// whole sequences.  No coordinator code is involved, so agreement pins
/// both the outputs and the counter evolution of the granted path.
struct RefModel {
    n: usize,
    d: usize,
    layers: usize,
    heads: usize,
    capacity: usize,
    top_w: usize,
    family: SpecFamily,
    backend: Arc<dyn Backend>,
    session: RoutingSession,
    cache: EpochCache,
    budget: MemoryBudget,
    members: Vec<MemberCache>,
    local: AttentionSpec,
    static_pattern: Arc<CompiledPattern>,
    regen: RegenStats,
}

impl RefModel {
    fn new(cfg: &CoordinatorConfig) -> RefModel {
        let backend = backend::lookup(&cfg.backend).expect("registered backend");
        let session =
            RoutingSession::new(cfg.layers, cfg.heads, cfg.clusters, cfg.d, 0.5, cfg.seed)
                .unwrap();
        let budget = MemoryBudget::unbounded();
        let mut cache = EpochCache::with_budget(budget.clone());
        let local = AttentionSpec::local(cfg.window).unwrap();
        let static_pattern = cache.get_static(&local, cfg.n);
        let members = (0..cfg.layers * cfg.heads * cfg.capacity)
            .map(|_| MemberCache::with_budget(budget.clone()))
            .collect();
        RefModel {
            n: cfg.n,
            d: cfg.d,
            layers: cfg.layers,
            heads: cfg.heads,
            capacity: cfg.capacity,
            top_w: cfg.top_w,
            family: cfg.spec_family,
            backend,
            session,
            cache,
            budget,
            members,
            local,
            static_pattern,
            regen: RegenStats::default(),
        }
    }

    fn update(&mut self, layer: usize, head: usize, xs: &[f32], n: usize) -> RouteUpdate {
        self.session.update(layer, head, xs, n)
    }

    fn static_attention(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> (Vec<f32>, u64) {
        let cost = self.static_pattern.cost(self.d);
        let out = self.backend.attention(q, k, v, self.d, &self.static_pattern).unwrap();
        (out, cost)
    }

    #[allow(clippy::too_many_arguments)]
    fn routed_attention(
        &mut self,
        layer: usize,
        head: usize,
        slot: usize,
        xs: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, u64) {
        let epoch = self.session.epoch(layer, head);
        let ae = self.session.assignment_epoch(layer, head);
        let idx = (layer * self.heads + head) * self.capacity + slot;
        let (n, top_w) = (self.n, self.top_w);
        let family = self.family;
        let pattern = {
            let RefModel { ref mut cache, ref session, ref mut members, ref local, .. } = *self;
            let mc = &mut members[idx];
            cache.get_routed_at(RouteSlot { layer, head, seq: slot }, epoch, ae, n, || {
                AttentionSpec::union(vec![
                    local.clone(),
                    routed_family_spec(family, session, layer, head, mc, xs, n, top_w),
                ])
                .expect("non-empty union of valid specs")
            })
        };
        let cost = pattern.cost(self.d);
        let out = self.backend.attention(q, k, v, self.d, &pattern).unwrap();
        (out, cost)
    }

    fn retire(&mut self, slot: usize) {
        for layer in 0..self.layers {
            for head in 0..self.heads {
                let idx = (layer * self.heads + head) * self.capacity + slot;
                let budget = self.budget.clone();
                let mc = &mut self.members[idx];
                self.regen.merge(mc.stats());
                *mc = MemberCache::with_budget(budget);
            }
        }
    }

    fn regen_total(&self) -> RegenStats {
        let mut total = self.regen;
        for mc in &self.members {
            total.merge(mc.stats());
        }
        total
    }
}

// ------------------------------------------------------ wire round-trips

fn random_spec(rng: &mut Rng, depth: usize) -> AttentionSpec {
    let kinds = if depth == 0 { 7 } else { 9 };
    match rng.below(kinds) {
        0 => AttentionSpec::full(),
        1 => AttentionSpec::local(rng.range(1, 9)).unwrap(),
        2 => AttentionSpec::block_local(rng.range(1, 9)).unwrap(),
        3 => AttentionSpec::strided(rng.range(1, 9)).unwrap(),
        4 => AttentionSpec::routing(
            (0..rng.range(1, 4))
                .map(|_| (0..rng.below(4)).map(|_| rng.below(32)).collect())
                .collect(),
        ),
        5 => {
            let capacity = rng.range(0, 6);
            AttentionSpec::expert_choice(
                (0..rng.range(1, 4))
                    .map(|_| {
                        let mut m: Vec<usize> =
                            (0..rng.below(4)).map(|_| rng.below(32)).collect();
                        m.sort_unstable();
                        m.dedup();
                        m.truncate(capacity);
                        m
                    })
                    .collect(),
                capacity,
            )
            .unwrap()
        }
        6 => AttentionSpec::threshold(
            (0..rng.below(6)).map(|i| (0..=i).filter(|_| rng.chance(0.4)).collect()).collect(),
        )
        .unwrap(),
        n => {
            let parts = (0..rng.range(1, 4)).map(|_| random_spec(rng, depth - 1)).collect();
            if n == 7 {
                AttentionSpec::union(parts).unwrap()
            } else {
                AttentionSpec::intersect(parts).unwrap()
            }
        }
    }
}

fn random_delta(rng: &mut Rng) -> AssignmentDelta {
    let moved = (0..rng.below(5))
        .map(|_| (rng.below(1 << 20), rng.below(256), rng.below(256)))
        .collect();
    AssignmentDelta {
        counts: (0..rng.range(1, 6)).map(|_| rng.below(1 << 20)).collect(),
        moved,
        assigned: rng.below(1 << 20),
    }
}

#[test]
fn prop_wire_spec_and_delta_roundtrip() {
    // Every spec family (plus Union/Intersect nesting) and every
    // AssignmentDelta/RouteUpdate survives its wire JSON form exactly —
    // the payloads the coordinator ships in `spec` installs and `delta`
    // broadcasts.
    check("wire_roundtrip", 150, |rng| {
        let spec = random_spec(rng, 1);
        let back = AttentionSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec, "spec -> json -> spec must be identity");
        let delta = random_delta(rng);
        assert_eq!(AssignmentDelta::from_json(&delta.to_json()).unwrap(), delta);
        let upd = RouteUpdate {
            epoch: rng.next_u64() >> 12,
            assignment_epoch: rng.next_u64() >> 12,
            delta: random_delta(rng),
        };
        assert_eq!(RouteUpdate::from_json(&upd.to_json()).unwrap(), upd);
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let kinds = if depth == 0 { 4 } else { 6 };
    match rng.below(kinds) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num(rng.normal()),
        3 => Json::Str((0..rng.below(12)).map(|_| char::from(rng.range(32, 127) as u8)).collect()),
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_frame_roundtrip() {
    // Arbitrary JSON values survive the length-prefixed frame layer:
    // every frame reads back equal, a clean EOF lands exactly on the
    // frame boundary, and a truncated tail is an error — never a
    // silently short read.
    check("frame_roundtrip", 100, |rng| {
        let msgs: Vec<Json> = (0..rng.range(1, 6)).map(|_| random_json(rng, 2)).collect();
        let mut buf = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, m).unwrap();
        }
        let cut = rng.range(1, buf.len());
        let mut r = std::io::Cursor::new(buf.clone());
        for m in &msgs {
            assert_eq!(read_frame(&mut r).unwrap().expect("frame present"), *m);
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at the boundary");
        let mut truncated = std::io::Cursor::new(buf[..cut].to_vec());
        loop {
            match read_frame(&mut truncated) {
                Ok(Some(_)) => continue,
                Ok(None) => break, // cut landed exactly on a frame boundary
                Err(_) => break,   // mid-frame EOF must error
            }
        }
    });
}

// ------------------------------------------- the model-based tentpole

#[test]
fn prop_coordinator_matches_single_process_model_under_faults() {
    // Random op sequences with scheduled faults: the coordinated path
    // must stay bit-identical to the single-process reference and keep
    // its ledger conserved after arbitrary crash/rejoin interleavings —
    // under every spec family (routing, expert-choice, threshold).
    check("coordinator_vs_model", 40, |rng| {
        let backends = ["reference", "blocked", "simd"];
        let families = [SpecFamily::Routing, SpecFamily::ExpertChoice, SpecFamily::Threshold];
        let cfg = CoordinatorConfig {
            n: rng.range(8, 25),
            d: rng.range(2, 5),
            layers: rng.range(1, 3),
            heads: 2,
            window: rng.range(2, 7),
            clusters: rng.range(2, 5),
            top_w: rng.range(2, 7),
            capacity: 2,
            seed: rng.next_u64(),
            backend: backends[rng.below(backends.len())].to_string(),
            max_regrants: rng.range(1, 5) as u64,
            spec_family: families[rng.below(families.len())],
        };
        let mut coord = Coordinator::new(cfg.clone(), SimTransport::new()).unwrap();
        let mut model = RefModel::new(&cfg);
        let mut expected_rows = 0u64;
        let mut spawned: Vec<WorkerId> = Vec::new();
        for _op in 0..rng.range(12, 22) {
            match rng.below(10) {
                0 => {
                    if spawned.len() < 4 {
                        spawned.push(coord.spawn_worker().unwrap());
                    }
                }
                1 => {
                    if let Some(&w) = pick(rng, &spawned) {
                        coord.kill_worker(w);
                        assert_eq!(coord.worker_state(w), Some(WorkerState::Crashed));
                    }
                }
                2 => {
                    let crashed: Vec<WorkerId> = spawned
                        .iter()
                        .copied()
                        .filter(|&w| coord.worker_state(w) == Some(WorkerState::Crashed))
                        .collect();
                    if let Some(&w) = pick(rng, &crashed) {
                        coord.rejoin_worker(w).unwrap();
                        assert_eq!(coord.worker_state(w), Some(WorkerState::Joining));
                    }
                }
                3 => {
                    if let Some(&w) = pick(rng, &spawned) {
                        let nth = rng.range(1, 4) as u64;
                        let t = coord.transport_mut();
                        match rng.below(4) {
                            0 => t.inject_drop_next(w),
                            1 => t.inject_duplicate_next(w),
                            2 => t.inject_delay_next(w),
                            _ => t.crash_on_nth_message(w, nth),
                        }
                    }
                }
                4..=5 => {
                    let layer = rng.below(cfg.layers);
                    let head = rng.below(cfg.heads);
                    let xs = vecs(rng, cfg.n * cfg.d);
                    let got = coord.update(layer, head, &xs, cfg.n).unwrap();
                    let want = model.update(layer, head, &xs, cfg.n);
                    assert_eq!(got, want, "RouteUpdate parity (same seed, same batch)");
                }
                6..=8 => {
                    coord.mark_step();
                    model.cache.mark_step();
                    for _ in 0..rng.range(1, 4) {
                        let q = vecs(rng, cfg.n * cfg.d);
                        let k = vecs(rng, cfg.n * cfg.d);
                        let v = vecs(rng, cfg.n * cfg.d);
                        if rng.chance(0.4) {
                            let (got, gc) = coord.static_attention(&q, &k, &v).unwrap();
                            let (want, wc) = model.static_attention(&q, &k, &v);
                            assert_bits_eq(&got, &want, "static output under faults");
                            assert_eq!(gc, wc, "static MAC cost");
                        } else {
                            let layer = rng.below(cfg.layers);
                            let head = rng.below(cfg.heads);
                            let slot = rng.below(cfg.capacity);
                            let xs = vecs(rng, cfg.n * cfg.d);
                            let (got, gc) =
                                coord.routed_attention(layer, head, slot, &xs, &q, &k, &v).unwrap();
                            let (want, wc) =
                                model.routed_attention(layer, head, slot, &xs, &q, &k, &v);
                            assert_bits_eq(&got, &want, "routed output under faults");
                            assert_eq!(gc, wc, "routed MAC cost");
                        }
                        expected_rows += cfg.n as u64;
                    }
                }
                _ => {
                    let slot = rng.below(cfg.capacity);
                    if rng.chance(0.5) {
                        coord.retire_slot(slot).unwrap();
                        model.retire(slot);
                    } else {
                        let layer = rng.below(cfg.layers);
                        let head = rng.below(cfg.heads);
                        let got = coord.evict_slot(layer, head, slot).unwrap();
                        let want = model.cache.evict_slot(RouteSlot { layer, head, seq: slot });
                        assert_eq!(got, want, "evicted-bytes parity");
                    }
                }
            }
            let st = coord.stats();
            assert!(st.conserved(), "ledger conservation at rest: {st:?}");
            assert_eq!(
                st.worker_rows + st.inline_rows,
                expected_rows,
                "every row-range completes exactly once: {st:?}"
            );
        }
        coord.pump().unwrap();
        let st = coord.stats();
        assert!(st.conserved(), "final conservation: {st:?}");
        assert_eq!(st.worker_rows + st.inline_rows, expected_rows);
        assert!(
            st.regrants <= st.superseded + st.voided,
            "every re-grant follows a supersession or a void: {st:?}"
        );
        // routing-state counter parity: the coordinator replays the
        // in-process call sequence exactly
        assert_eq!(coord.cache_stats(), model.cache.stats(), "compile-cache counters");
        assert_eq!(coord.epoch_stats(), model.cache.epoch_stats(), "epoch-cache counters");
        assert_eq!(coord.regen_total(), model.regen_total(), "membership regen counters");
        assert_eq!(coord.live_patterns(), model.cache.len(), "live compiles");
        for &w in &spawned {
            assert!(coord.worker_state(w).is_some(), "spawned workers never vanish");
        }
        coord.shutdown();
        for &w in &spawned {
            assert_eq!(coord.worker_state(w), Some(WorkerState::Crashed), "shutdown kills all");
        }
    });
}

#[test]
fn prop_crash_mid_grant_regrants_exactly_once_and_rejoin_restores() {
    // The scripted core of the fault story, across random shapes: a
    // worker that crashes on receipt of its grant gets its row-range
    // voided exactly once and re-granted to the survivor (outputs still
    // bit-identical); a rejoin re-runs the full install handshake; with
    // every worker dead the coordinator computes inline.
    check("crash_rejoin_exactly_once", 60, |rng| {
        let cfg = CoordinatorConfig {
            n: rng.range(8, 21),
            d: rng.range(2, 5),
            layers: 1,
            heads: 2,
            window: rng.range(2, 5),
            clusters: 2,
            top_w: 4,
            capacity: 2,
            seed: rng.next_u64(),
            backend: "reference".to_string(),
            max_regrants: 8,
            spec_family: SpecFamily::Routing,
        };
        let n = cfg.n;
        let mut coord = Coordinator::new(cfg.clone(), SimTransport::new()).unwrap();
        let mut model = RefModel::new(&cfg);
        let w0 = coord.spawn_worker().unwrap();
        let w1 = coord.spawn_worker().unwrap();
        let q = vecs(rng, cfg.n * cfg.d);
        let k = vecs(rng, cfg.n * cfg.d);
        let v = vecs(rng, cfg.n * cfg.d);

        // 1: both workers compute; nothing inline
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        let (want, _) = model.static_attention(&q, &k, &v);
        assert_bits_eq(&got, &want, "two healthy workers");
        let st = coord.stats();
        assert_eq!(st.joins, 2);
        assert_eq!(st.worker_rows, n as u64, "all rows computed on workers");
        assert_eq!(st.inline_rows, 0);
        assert!(st.conserved());

        // 2: w0 crashes the moment its next grant arrives
        coord.transport_mut().crash_on_nth_message(w0, 1);
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_bits_eq(&got, &want, "crash mid-grant");
        let st = coord.stats();
        assert_eq!(st.crashes, 1);
        assert_eq!(st.voided, 1, "the crashed worker's grant voided exactly once");
        assert_eq!(st.regrants, 1, "its row-range re-granted to the survivor");
        assert_eq!(st.worker_rows, 2 * n as u64, "the survivor picked the rows up");
        assert_eq!(st.inline_rows, 0);
        assert!(st.conserved());
        assert_eq!(coord.worker_state(w0), Some(WorkerState::Crashed));
        assert_eq!(coord.transport_mut().faults().forced_crashes, 1);

        // 3: rejoin re-runs the install handshake; both grantable again
        coord.rejoin_worker(w0).unwrap();
        coord.pump().unwrap();
        assert_eq!(coord.worker_state(w0), Some(WorkerState::Ready));
        assert_eq!(coord.stats().rejoins, 1);
        assert_eq!(coord.stats().joins, 3, "a rejoin is a fresh join handshake");
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_bits_eq(&got, &want, "after rejoin");
        let st = coord.stats();
        assert_eq!(st.worker_rows, 3 * n as u64);
        assert_eq!(st.inline_rows, 0);

        // 4: every worker dead -> inline fallback, still bit-identical
        coord.kill_worker(w0);
        coord.kill_worker(w1);
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_bits_eq(&got, &want, "all workers dead");
        let st = coord.stats();
        assert_eq!(st.inline_rows, n as u64, "orphaned call computed inline");
        assert_eq!(st.worker_rows, 3 * n as u64);
        assert!(st.conserved());
        coord.shutdown();
    });
}

#[test]
fn prop_dropped_grant_supersedes_and_stale_replies_are_rejected() {
    // A dropped grant leaves the transport quiet: the coordinator
    // supersedes the outstanding grant and re-grants; a delayed reply
    // arriving after its epoch moved is rejected as stale, and a
    // duplicated reply at the current epoch is rejected as a duplicate —
    // in every case rows land exactly once.
    check("drop_delay_duplicate", 60, |rng| {
        let cfg = CoordinatorConfig {
            n: rng.range(8, 17),
            d: 3,
            layers: 1,
            heads: 2,
            window: 3,
            clusters: 2,
            top_w: 4,
            capacity: 2,
            seed: rng.next_u64(),
            backend: "reference".to_string(),
            max_regrants: 8,
            spec_family: SpecFamily::Routing,
        };
        let n = cfg.n as u64;
        let mut coord = Coordinator::new(cfg.clone(), SimTransport::new()).unwrap();
        let mut model = RefModel::new(&cfg);
        let w0 = coord.spawn_worker().unwrap();
        let q = vecs(rng, cfg.n * cfg.d);
        let k = vecs(rng, cfg.n * cfg.d);
        let v = vecs(rng, cfg.n * cfg.d);
        coord.pump().unwrap();
        assert_eq!(coord.worker_state(w0), Some(WorkerState::Ready));

        // dropped grant: quiet transport -> supersede -> re-grant works
        coord.transport_mut().inject_drop_next(w0);
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        let (want, _) = model.static_attention(&q, &k, &v);
        assert_bits_eq(&got, &want, "dropped grant");
        let st = coord.stats();
        assert_eq!(st.superseded, 1, "the lost grant was superseded exactly once");
        assert_eq!(st.regrants, 1);
        assert_eq!(st.worker_rows + st.inline_rows, n, "rows land exactly once");
        assert!(st.conserved());
        assert_eq!(coord.transport_mut().faults().dropped, 1);

        // duplicated reply: the second copy has no outstanding grant and
        // is rejected (duplicate at the current epoch, or stale if an
        // update moved the epoch before it surfaced)
        coord.transport_mut().inject_duplicate_next(w0);
        let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_bits_eq(&got, &want, "duplicated reply");
        coord.pump().unwrap();
        let st = coord.stats();
        assert_eq!(
            st.rejected_duplicate + st.rejected_stale_epoch,
            1,
            "the duplicate was rejected, not double-written: {st:?}"
        );
        assert_eq!(st.worker_rows + st.inline_rows, 2 * n, "no double-counted rows");
        assert!(st.conserved());
        coord.shutdown();
    });
}

// -------------------------------------- coordinated serve ≡ in-process

#[test]
fn prop_serve_coordinated_matches_in_process_bit_for_bit() {
    // The whole-loop contract behind `rtx serve --workers N`: the
    // coordinator-backed serve loop produces the same output digest, the
    // same outcome ledger, and the same cache/epoch/regen counters as
    // the in-process loop — even with faults scheduled mid-run, for
    // every `--spec` family.
    check("serve_coordinated", 12, |rng| {
        let families = [SpecFamily::Routing, SpecFamily::ExpertChoice, SpecFamily::Threshold];
        let opts = ServeOptions {
            n: rng.range(12, 21),
            spec_family: families[rng.below(families.len())],
            d: 3,
            layers: rng.range(1, 3),
            heads: 2,
            window: 3,
            clusters: 2,
            top_w: 4,
            workers: 2,
            capacity: 2,
            route_every: rng.range(1, 4) as u64,
            arrivals: ArrivalConfig {
                requests: rng.range(4, 9),
                rate: 1.0,
                contents: 4,
                zipf_s: 1.1,
                work: (1, 4),
                slack: (4, 16),
                seed: rng.next_u64(),
            },
            seed: rng.next_u64(),
            ..ServeOptions::default()
        };
        let backend = backend::lookup("reference").unwrap();
        let baseline = run_serve(&opts, &*backend).unwrap();

        let cfg = CoordinatorConfig {
            n: opts.n,
            d: opts.d,
            layers: opts.layers,
            heads: opts.heads,
            window: opts.window,
            clusters: opts.clusters,
            top_w: opts.top_w,
            capacity: opts.capacity,
            seed: opts.seed,
            backend: "reference".to_string(),
            spec_family: opts.spec_family,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, SimTransport::new()).unwrap();
        let w0 = coord.spawn_worker().unwrap();
        let w1 = coord.spawn_worker().unwrap();
        // schedule faults before the run: a dropped frame, a delayed
        // reply, and a mid-run crash of one worker
        coord.transport_mut().inject_drop_next(w0);
        coord.transport_mut().inject_delay_next(w1);
        coord.transport_mut().crash_on_nth_message(w1, rng.range(2, 20) as u64);
        let got = run_serve_coordinated(&opts, &mut coord).unwrap();
        coord.shutdown();

        assert_eq!(got.output_digest, baseline.output_digest, "bit-identical attention bytes");
        assert_eq!(got.stats, baseline.stats, "request-lifecycle counters");
        assert_eq!(got.outcomes, baseline.outcomes, "outcome ledger, exact order");
        assert_eq!(got.batched_rows, baseline.batched_rows);
        assert_eq!(got.macs, baseline.macs);
        assert_eq!(got.virtual_steps, baseline.virtual_steps);
        assert_eq!(got.cache, baseline.cache, "compile-cache counters");
        assert_eq!(got.epoch, baseline.epoch, "epoch-cache counters");
        assert_eq!(got.regen, baseline.regen, "membership regen counters");
        assert_eq!(got.live_patterns_after_gc, baseline.live_patterns_after_gc);
        assert_eq!(got.peak_pattern_bytes, baseline.peak_pattern_bytes);
        assert_eq!(got.pattern_bytes_resident, baseline.pattern_bytes_resident);
        assert_eq!(got.pattern_bytes_evicted, baseline.pattern_bytes_evicted);
        assert_eq!(got.gc_bytes_reclaimed, baseline.gc_bytes_reclaimed);
        assert_eq!(baseline.worker_procs, 0);
        assert_eq!(got.worker_procs, 2);
        let co = got.coord.expect("coordinated run reports its ledger");
        assert!(co.conserved(), "serve-loop ledger conserved: {co:?}");
    });
}

// ----------------------------------------------- real child processes

#[test]
fn process_transport_runs_real_workers_bit_identically() {
    // End to end over OS pipes: spawn two real `rtx worker` subprocesses
    // (the binary under test, via CARGO_BIN_EXE_rtx), split static and
    // routed sweeps across them, kill one child, and verify outputs stay
    // bit-identical to the single-process reference throughout.
    let exe = env!("CARGO_BIN_EXE_rtx");
    let mut transport = ProcessTransport::new(exe);
    transport.set_poll_timeout(Duration::from_secs(60));
    let cfg = CoordinatorConfig {
        n: 32,
        d: 4,
        layers: 1,
        heads: 2,
        window: 4,
        clusters: 2,
        top_w: 8,
        capacity: 2,
        seed: 42,
        backend: "reference".to_string(),
        max_regrants: 8,
        spec_family: SpecFamily::Routing,
    };
    let mut coord = Coordinator::new(cfg.clone(), transport).unwrap();
    let mut model = RefModel::new(&cfg);
    let w0 = coord.spawn_worker().unwrap();
    let w1 = coord.spawn_worker().unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    while coord.worker_state(w0) != Some(WorkerState::Ready)
        || coord.worker_state(w1) != Some(WorkerState::Ready)
    {
        coord.pump().unwrap();
        assert!(Instant::now() < deadline, "workers failed to join within 60s");
        std::thread::sleep(Duration::from_millis(10));
    }

    let mut rng = Rng::new(0xFEED);
    let q = vecs(&mut rng, cfg.n * cfg.d);
    let k = vecs(&mut rng, cfg.n * cfg.d);
    let v = vecs(&mut rng, cfg.n * cfg.d);

    let (got, cost) = coord.static_attention(&q, &k, &v).unwrap();
    let (want, wcost) = model.static_attention(&q, &k, &v);
    assert_bits_eq(&got, &want, "static sweep over real subprocesses");
    assert_eq!(cost, wcost);

    let xs = vecs(&mut rng, cfg.n * cfg.d);
    let got_u = coord.update(0, 1, &xs, cfg.n).unwrap();
    let want_u = model.update(0, 1, &xs, cfg.n);
    assert_eq!(got_u, want_u, "RouteUpdate parity over the wire");
    let (got, cost) = coord.routed_attention(0, 1, 0, &xs, &q, &k, &v).unwrap();
    let (want, wcost) = model.routed_attention(0, 1, 0, &xs, &q, &k, &v);
    assert_bits_eq(&got, &want, "routed sweep over real subprocesses");
    assert_eq!(cost, wcost);

    let st = coord.stats();
    assert!(st.conserved(), "{st:?}");
    assert_eq!(st.joins, 2);
    assert_eq!(st.worker_rows, 2 * cfg.n as u64, "both sweeps ran on the children");
    assert_eq!(st.inline_rows, 0);

    // kill one real child; the survivor (or inline fallback) covers
    coord.kill_worker(w0);
    let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
    let (want, _) = model.static_attention(&q, &k, &v);
    assert_bits_eq(&got, &want, "after killing one child process");
    let st = coord.stats();
    assert!(st.conserved(), "{st:?}");
    assert_eq!(st.worker_rows + st.inline_rows, 3 * cfg.n as u64, "rows land exactly once");
    assert!(st.crashes >= 1);
    coord.shutdown();
}

// --------------------------------------------------- harness self-check

#[test]
fn regression_seed_files_are_well_formed() {
    // Every non-comment line in every checked-in regression file must
    // parse as `<property> 0x<seed>` — a malformed line would silently
    // skip replay.
    for (file, text) in [
        ("coordinator", REGRESSIONS),
        ("proptests", include_str!("../proptest-regressions/proptests.txt")),
        ("stateful", include_str!("../proptest-regressions/stateful.txt")),
    ] {
        let content_lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .count();
        let parsed = common::parse_seeds(text);
        assert_eq!(
            parsed.len(),
            content_lines,
            "every non-comment line in proptest-regressions/{file}.txt must parse"
        );
        assert!(!parsed.is_empty(), "{file}.txt should keep its anchor seeds");
    }
}

//! Documentation drift guard: every `rtx <subcommand>` named inside a
//! code fence of the top-level `README.md` / `ARCHITECTURE.md` must be a
//! subcommand the CLI actually dispatches (the `match cmd` arms in
//! `src/main.rs`), so the quickstart can never rot silently when a
//! subcommand is renamed or removed.  Everything is `include_str!`-ed at
//! compile time, so this runs in the host-only (no-xla) CI job — where
//! the `rtx` binary itself now also builds (its PJRT subcommands are
//! cfg-gated and bail with a build hint).

use std::collections::BTreeSet;

const MAIN_RS: &str = include_str!("../src/main.rs");
const README: &str = include_str!("../../README.md");
const ARCHITECTURE: &str = include_str!("../../ARCHITECTURE.md");

/// Subcommand names dispatched by `fn run`: the first string literal of
/// every match arm inside the `match cmd {` block.
fn subcommands_from_main() -> BTreeSet<String> {
    let start = MAIN_RS.find("match cmd {").expect("main.rs must dispatch via `match cmd {`");
    let block = &MAIN_RS[start..];
    let end = block.find("\n    }").expect("match block must close");
    let mut names = BTreeSet::new();
    for line in block[..end].lines() {
        let Some((head, _)) = line.split_once("=>") else { continue };
        // a head may hold several patterns: `"help" | _ =>`
        for pat in head.split('|') {
            let pat = pat.trim();
            if let Some(name) = pat.strip_prefix('"').and_then(|p| p.strip_suffix('"')) {
                names.insert(name.to_string());
            }
        }
    }
    assert!(
        names.contains("serve-bench") && names.contains("figure1") && names.contains("serve"),
        "subcommand extraction looks broken: got {names:?}"
    );
    names
}

/// `rtx <word>` references inside fenced code blocks (``` ... ```);
/// returns (doc-name, line, subcommand) triples.
fn fenced_rtx_refs(doc_name: &str, doc: &str) -> Vec<(String, usize, String)> {
    let mut refs = Vec::new();
    let mut in_fence = false;
    for (ln, line) in doc.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if !in_fence {
            continue;
        }
        let mut tokens = line.split_whitespace().peekable();
        while let Some(tok) = tokens.next() {
            if tok != "rtx" && tok != "./rtx" {
                continue;
            }
            if let Some(&next) = tokens.peek() {
                // `rtx --help` style lines name no subcommand; skip flags
                if !next.starts_with('-') {
                    refs.push((doc_name.to_string(), ln + 1, next.to_string()));
                }
            }
        }
    }
    refs
}

#[test]
fn doc_code_fences_name_real_rtx_subcommands() {
    let valid = subcommands_from_main();
    let mut refs = fenced_rtx_refs("README.md", README);
    refs.extend(fenced_rtx_refs("ARCHITECTURE.md", ARCHITECTURE));
    assert!(
        !refs.is_empty(),
        "the docs must demonstrate at least one `rtx` invocation in a code fence"
    );
    for (doc, line, sub) in &refs {
        assert!(
            valid.contains(sub),
            "{doc}:{line} names `rtx {sub}`, which is not a dispatched subcommand \
             (valid: {valid:?})"
        );
    }
}

#[test]
fn docs_exist_and_are_cross_linked() {
    assert!(
        README.contains("ARCHITECTURE.md"),
        "README.md must link the architecture document"
    );
    assert!(
        ARCHITECTURE.contains("serve-bench"),
        "ARCHITECTURE.md must document the serving pipeline / bench schema"
    );
    assert!(
        README.contains("--no-default-features"),
        "README.md must document the host-only build matrix"
    );
    assert!(
        README.contains("RTX_WORKERS"),
        "README.md must document the worker-pool sizing override"
    );
    // the serve layer ships with docs: the continuous-batching front-end,
    // its persisted perf trajectory, and the versioned --json schema
    assert!(
        README.contains("rtx serve"),
        "README.md must document the continuous-batching serve command"
    );
    assert!(
        ARCHITECTURE.contains("BENCH_serve.json"),
        "ARCHITECTURE.md must document the persisted serve perf trajectory"
    );
    assert!(
        ARCHITECTURE.contains("evict_slot"),
        "ARCHITECTURE.md must document the retirement GC path"
    );
    // the memory-bounded compilation layer ships with docs: the banded
    // compile path, the byte budget, the new serve flags, and the
    // byte-accounting fields
    assert!(
        ARCHITECTURE.contains("Memory-bounded compilation"),
        "ARCHITECTURE.md must document the banded compilation layer"
    );
    assert!(
        ARCHITECTURE.contains("compile_band"),
        "ARCHITECTURE.md must document the band compile entry point"
    );
    assert!(
        ARCHITECTURE.contains("\"schema\": 6"),
        "ARCHITECTURE.md must document the current schema-6 --json line"
    );
    // the exactness contract ships with docs: which backend declares
    // what, and the simd fast-math tier that motivates the Ulps budget
    assert!(
        ARCHITECTURE.contains("Exactness contract"),
        "ARCHITECTURE.md must document the exactness verification contract"
    );
    assert!(
        ARCHITECTURE.contains("Ulps"),
        "ARCHITECTURE.md must document the ulps tolerance tier"
    );
    assert!(
        README.contains("simd"),
        "README.md must document the simd fast-math backend"
    );
    assert!(
        ARCHITECTURE.contains("peak_pattern_bytes"),
        "ARCHITECTURE.md must document the peak-resident-bytes field"
    );
    assert!(
        README.contains("--max-pattern-bytes") && README.contains("--band-rows"),
        "README.md must document the memory-bounded serve flags"
    );
    assert!(
        README.contains("--render-rows"),
        "README.md must document the figure1 render clip flag"
    );
    // the multi-process coordinator ships with docs: the worker
    // subcommand, the process-count flag (and its rename of the old
    // intra-process chunking flag to --shards), the wire frame format,
    // the worker state machine, and the fault model the coordinator
    // suite pins
    assert!(
        ARCHITECTURE.contains("Multi-process coordination"),
        "ARCHITECTURE.md must document the coordinator layer"
    );
    assert!(
        ARCHITECTURE.contains("length-prefixed") && ARCHITECTURE.contains("big-endian"),
        "ARCHITECTURE.md must document the wire frame format"
    );
    assert!(
        ARCHITECTURE.contains("Joining") && ARCHITECTURE.contains("Crashed"),
        "ARCHITECTURE.md must document the worker state machine"
    );
    assert!(
        ARCHITECTURE.contains("output_digest"),
        "ARCHITECTURE.md must document the bit-identity digest anchor"
    );
    assert!(
        README.contains("rtx worker"),
        "README.md must document the worker subcommand"
    );
    assert!(
        README.contains("--workers") && README.contains("--shards"),
        "README.md must document the process-count and shard-count flags"
    );
    // the content-based spec families ship with docs: the family table
    // and its invariants, the schema-6 observables, and the serve flag
    assert!(
        ARCHITECTURE.contains("Content-based spec families"),
        "ARCHITECTURE.md must document the spec-family layer"
    );
    assert!(
        ARCHITECTURE.contains("spec_family") && ARCHITECTURE.contains("max_cluster_nnz"),
        "ARCHITECTURE.md must document the schema-6 spec-family fields"
    );
    assert!(
        ARCHITECTURE.contains("max_shard_nnz"),
        "ARCHITECTURE.md must document the shard load-balance observables"
    );
    assert!(
        README.contains("--spec") && README.contains("expert-choice") && README.contains("threshold"),
        "README.md must document the --spec family selector"
    );
}

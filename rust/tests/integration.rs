//! Integration tests over the real AOT artifacts: load -> compile ->
//! train -> eval -> checkpoint -> sample, asserting the end-to-end
//! contracts (shapes, loss decrease, determinism, retrieval advantage).
//!
//! Requires `make artifacts` (skipped gracefully if missing so plain
//! `cargo test` works in a fresh checkout) and the `xla` feature (the
//! whole file drives the PJRT runtime, so it compiles to nothing under
//! `--no-default-features`).

#![cfg(feature = "xla")]

use std::path::PathBuf;

use routing_transformer::coordinator::{
    eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions, Trainer,
};
use routing_transformer::runtime::{Artifacts, ModelState, Runtime};
use routing_transformer::sampler::{Generator, SamplerConfig};

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    root().join("quickstart/manifest.json").exists()
}

/// Fresh PJRT client per test: the xla crate's client is Rc-based (not
/// Send/Sync), so it cannot be shared across cargo's test threads.
fn runtime() -> Runtime {
    Runtime::cpu().expect("PJRT CPU client")
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifest_loads_and_is_consistent() {
    require_artifacts!();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let m = &art.manifest;
    assert_eq!(m.variant, "quickstart");
    assert!(m.params.len() > 10);
    assert_eq!(m.config.plan.len(), m.config.n_layers);
    // routing layer (top) must have a centroid parameter
    assert_eq!(m.routing_layers().len(), 1);
    let total: usize = m.params.iter().map(|p| p.numel()).sum();
    assert_eq!(total, m.n_params_total);
}

#[test]
fn init_state_matches_manifest() {
    require_artifacts!();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let state = art.init_state().unwrap();
    assert_eq!(state.params.len(), art.manifest.params.len());
    assert_eq!(state.numel(), art.manifest.n_params_total);
    assert_eq!(state.step, 0);
}

#[test]
fn train_block_decreases_loss_and_is_deterministic() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = art.manifest.clone();

    let run = || {
        let mut trainer = Trainer::new(rt, &art).unwrap();
        let mut batcher = train_batcher(&manifest, "needle", 0).unwrap();
        let opts = TrainOptions {
            steps: 16,
            schedule: LrSchedule::Constant { lr: 1e-3 },
            log_every: 0,
            ..Default::default()
        };
        trainer.train(&mut batcher, &manifest, &opts).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.losses, b.losses, "training must be bit-deterministic");
    assert!(
        a.mean_last10_loss < a.losses[0] as f64,
        "loss should decrease: first {} last10 {}",
        a.losses[0],
        a.mean_last10_loss
    );
    assert!(a.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn train_step_and_train_block_agree() {
    require_artifacts!();
    // the single-step artifact and the scanned block must produce the
    // same first-step loss from the same state and data
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = art.manifest.clone();

    let mut trainer = Trainer::new(rt, &art).unwrap();
    let mut batcher = train_batcher(&manifest, "needle", 3).unwrap();
    let block = batcher.next_block();
    let losses = trainer.step_block(&block, 1e-3).unwrap();

    // single-step path
    let exe = art.executable(rt, "train_step").unwrap();
    let state = art.init_state().unwrap();
    let tokens0 = &block.tokens[..manifest.batch * manifest.config.seq_len];
    let tok_lit = routing_transformer::runtime::i32_literal(
        tokens0,
        &[manifest.batch, manifest.config.seq_len],
    )
    .unwrap();
    let step_lit = routing_transformer::runtime::scalar_i32(0);
    let lr_lit = routing_transformer::runtime::scalar_f32(1e-3);
    let mut inputs: Vec<&xla::Literal> = Vec::new();
    inputs.extend(state.params.iter());
    inputs.extend(state.m.iter());
    inputs.extend(state.v.iter());
    inputs.push(&step_lit);
    inputs.push(&lr_lit);
    inputs.push(&tok_lit);
    let outs = routing_transformer::runtime::execute_tuple(&exe, &inputs).unwrap();
    let single_loss = routing_transformer::runtime::scalar_f32_value(outs.last().unwrap()).unwrap();
    assert!(
        (single_loss - losses[0]).abs() < 1e-5,
        "train_step {single_loss} vs train_block[0] {}",
        losses[0]
    );
}

#[test]
fn eval_runs_and_matches_vocab_entropy_at_init() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = &art.manifest;
    let state = art.init_state().unwrap();
    let evaluator = Evaluator::new(rt, &art).unwrap();
    let mut batcher = eval_batcher(manifest, "zipf", 1).unwrap();
    let report = evaluator.eval(&state, &mut batcher, 2).unwrap();
    // untrained model ~ uniform => nll near ln(V)
    let max_nll = (manifest.config.vocab_size as f64).ln();
    assert!(report.mean_nll > 0.5 * max_nll && report.mean_nll < 1.5 * max_nll,
            "init nll {} vs ln(V) {}", report.mean_nll, max_nll);
    assert_eq!(
        report.last_batch_nll.len(),
        manifest.batch * (manifest.config.seq_len - 1)
    );
}

#[test]
fn checkpoint_roundtrip_preserves_training() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = art.manifest.clone();
    let mut trainer = Trainer::new(rt, &art).unwrap();
    let mut batcher = train_batcher(&manifest, "needle", 5).unwrap();
    let block = batcher.next_block();
    trainer.step_block(&block, 1e-3).unwrap();

    let dir = std::env::temp_dir().join("rtx_it_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck");
    trainer.save(&manifest, &path).unwrap();
    let restored = ModelState::load(&manifest, &path).unwrap();
    assert_eq!(restored.step, trainer.state.step);

    // continuing from the checkpoint must equal continuing in-memory
    let block2 = batcher.next_block();
    let mut cont_mem = trainer;
    let losses_mem = cont_mem.step_block(&block2, 1e-3).unwrap();
    let mut cont_ckpt = Trainer::with_state(rt, &art, restored).unwrap();
    // with_state resets step to the loaded value; re-run the same block
    let losses_ckpt = cont_ckpt.step_block(&block2, 1e-3).unwrap();
    assert_eq!(losses_mem, losses_ckpt);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sampler_generates_in_vocab_and_deterministic() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = &art.manifest;
    let state = art.init_state().unwrap();
    let exe = art.executable(rt, "logits").unwrap();
    let gen = |seed| {
        let mut g = Generator::new(
            &exe,
            &state,
            manifest.config.seq_len,
            manifest.config.vocab_size,
            SamplerConfig::default(),
            seed,
        );
        g.generate(&[1, 2, 3], 8).unwrap()
    };
    let a = gen(9);
    let b = gen(9);
    let c = gen(10);
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|&t| (t as usize) < manifest.config.vocab_size));
    assert_eq!(a.len(), 11);
}

#[test]
fn routing_centroids_stay_unit_norm_through_training() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "quickstart").unwrap();
    let manifest = art.manifest.clone();
    let mut trainer = Trainer::new(rt, &art).unwrap();
    let mut batcher = train_batcher(&manifest, "needle", 0).unwrap();
    for _ in 0..3 {
        let block = batcher.next_block();
        trainer.step_block(&block, 1e-3).unwrap();
    }
    for (_, idx) in manifest.routing_layers() {
        let c = routing_transformer::runtime::to_f32_vec(&trainer.state.params[idx]).unwrap();
        let spec = &manifest.params[idx];
        let d = *spec.shape.last().unwrap();
        for row in c.chunks(d) {
            let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3, "centroid norm {norm}");
        }
    }
}

#[test]
fn routing_beats_local_on_needle_retrieval() {
    require_artifacts!();
    // The paper's core claim at reproduction scale: after identical short
    // training, the routing model's copy-target NLL improves over the
    // local model's (content-based retrieval beyond the local window).
    // Uses the needle_* pair (T=256, gap > 2*window).
    let rt = &runtime();
    let steps = 60;
    let mut nll = std::collections::BTreeMap::new();
    for variant in ["needle_routing", "needle_local"] {
        let art = Artifacts::load(&root(), variant).unwrap();
        let manifest = art.manifest.clone();
        let mut trainer = Trainer::new(rt, &art).unwrap();
        let mut batcher = train_batcher(&manifest, "needle", 0).unwrap();
        let opts = TrainOptions {
            steps,
            schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: 15 },
            log_every: 0,
            ..Default::default()
        };
        trainer.train(&mut batcher, &manifest, &opts).unwrap();
        let evaluator = Evaluator::new(rt, &art).unwrap();
        let mut eval = eval_batcher(&manifest, "needle", 11).unwrap();
        let (copy, _all) = evaluator
            .eval_retrieval(&trainer.state, &mut eval, 3, 4)
            .unwrap();
        nll.insert(variant, copy);
    }
    println!("copy-target nll: {:?}", nll);
    assert!(
        nll["needle_routing"] < nll["needle_local"] + 0.25,
        "routing should not be substantially worse at retrieval: {:?}",
        nll
    );
}

#[test]
fn attn_probs_artifact_rows_are_distributions() {
    require_artifacts!();
    let rt = &runtime();
    let art = Artifacts::load(&root(), "analysis").unwrap();
    let cfg = &art.manifest.config;
    let state = art.init_state().unwrap();
    let exe = art.executable(rt, "attn_probs").unwrap();
    let t = cfg.seq_len;
    let tokens: Vec<i32> = (0..t as i32).map(|i| i % cfg.vocab_size as i32).collect();
    let lit = routing_transformer::runtime::i32_literal(&tokens, &[1, t]).unwrap();
    let mut inputs: Vec<&xla::Literal> = state.params.iter().collect();
    inputs.push(&lit);
    let outs = routing_transformer::runtime::execute_tuple(&exe, &inputs).unwrap();
    let probs = routing_transformer::runtime::to_f32_vec(&outs[0]).unwrap();
    assert_eq!(probs.len(), cfg.n_layers * cfg.n_heads * t * t);
    // local head rows sum to 1; all rows sum to 1 or 0 (routing skips)
    let mut ones = 0usize;
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            for q in 0..t {
                let off = ((l * cfg.n_heads + h) * t + q) * t;
                let s: f32 = probs[off..off + t].iter().sum();
                assert!(
                    (s - 1.0).abs() < 1e-3 || s.abs() < 1e-4,
                    "row sum {s} at l={l} h={h} q={q}"
                );
                if (s - 1.0).abs() < 1e-3 {
                    ones += 1;
                }
            }
        }
    }
    assert!(ones > cfg.n_layers * t, "most rows should be real distributions");
}

//! Property-based tests over the coordinator substrates.
//!
//! The offline environment ships no `proptest`, so this file uses the
//! small hand-rolled property harness in `tests/common/mod.rs`: each
//! property replays the shrink seeds checked in under
//! `proptest-regressions/proptests.txt`, then runs over hundreds of
//! fresh seeded cases, reporting (and persisting) the failing seed for
//! shrink-by-hand reproduction.  Invariants covered: compiled attention
//! patterns (agreement with a naive reference oracle on `allowed`/`nnz`,
//! causality, row sortedness, spec JSON round-trips), routing membership,
//! expert-choice selection (disjoint argmax buckets, per-cluster
//! top-capacity vs a naive oracle, capacity-bounded nnz on every
//! compile), score-threshold attend sets (dense-score oracle with
//! NaN/±inf quarantine and floor top-up),
//! engine (shard partition, cache == fresh compile, kernel == oracle,
//! batched == B independent calls bit-for-bit, epoch-cache staleness +
//! eviction accounting, banded compilation == monolithic row slices,
//! byte-budgeted `ChunkedPattern` == monolithic compile bit-for-bit
//! under arbitrary tiny budgets), batcher (no loss/dup), k-means (norms,
//! assignment optimality), tokenizers (round-trips), sampler
//! (support/normalization), schedules (finiteness/monotonicity), JSON
//! (round-trip).

use std::sync::Arc;

use routing_transformer::analysis::{jsd, JSD_MAX};
use routing_transformer::attention::{
    assert_outputs_match, dense_masked_attention, optimal_clusters, sparse_attention,
    sparse_attention_batch, ulps_distance, values_match, AttentionSpec, Backend,
    BatchedAttention, ChunkedPattern, CompiledPattern, EpochCache, Exactness, MemoryBudget,
    PatternCache, Reference, RouteSlot, ShardedPattern, Simd,
};
#[cfg(feature = "xla")]
use routing_transformer::coordinator::LrSchedule;
use routing_transformer::data::{self, TokenSource};
use routing_transformer::kmeans::{dot, norm, SphericalKMeans};
use routing_transformer::sampler::{nucleus_probs, sample_logits, SamplerConfig};
use routing_transformer::tokenizer::{Bpe, ByteTokenizer, Tokenizer, WordVocab};
use routing_transformer::util::json::Json;
use routing_transformer::util::rng::Rng;

mod common;

/// Shrink seeds persisted from previous failures; replayed before the sweep.
const REGRESSIONS: &str = include_str!("../proptest-regressions/proptests.txt");

/// Run `f` over the recorded regression seeds, then `n` fresh seeded
/// cases; panic with the failing seed (persisting new failures).
fn check<F: Fn(&mut Rng)>(name: &str, n: usize, f: F) {
    common::check_with_regressions("proptests", REGRESSIONS, name, n, 0x5EED_0000, f);
}

// ------------------------------------------------------------- routing

#[test]
fn prop_top_w_members_balanced_sorted_unique() {
    check("top_w_balanced", 200, |rng| {
        let k = rng.range(1, 6);
        let dim = rng.range(2, 17);
        let n = rng.range(k, 65);
        let w = rng.range(1, n + 1);
        let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let km = SphericalKMeans::new(k, dim, 0.5, rng.next_u64());
        let members = km.top_w_members(&xs, n, w);
        assert_eq!(members.len(), k);
        for m in &members {
            assert_eq!(m.len(), w.min(n), "balanced clusters (Alg.1)");
            assert!(m.windows(2).all(|p| p[0] < p[1]), "sorted + unique");
            assert!(m.iter().all(|&i| i < n));
        }
    });
}

#[test]
fn prop_top_w_contains_argmax_member() {
    // each cluster's top-w must contain the single highest-dot vector
    check("top_w_argmax", 100, |rng| {
        let k = rng.range(1, 5);
        let dim = rng.range(2, 9);
        let n = rng.range(4, 33);
        let w = rng.range(1, n);
        let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let km = SphericalKMeans::new(k, dim, 0.5, rng.next_u64());
        let members = km.top_w_members(&xs, n, w);
        for (c, m) in members.iter().enumerate() {
            let mu = km.centroid(c);
            let best = (0..n)
                .max_by(|&a, &b| {
                    dot(mu, &xs[a * dim..(a + 1) * dim])
                        .partial_cmp(&dot(mu, &xs[b * dim..(b + 1) * dim]))
                        .unwrap()
                })
                .unwrap();
            let best_score = dot(mu, &xs[best * dim..(best + 1) * dim]);
            // some member must score >= best (ties allowed)
            assert!(
                m.iter().any(|&i| dot(mu, &xs[i * dim..(i + 1) * dim]) >= best_score - 1e-6),
                "top-w missing the argmax member"
            );
        }
    });
}

#[test]
fn prop_expert_choice_matches_per_cluster_top_capacity_oracle() {
    check("expert_choice_oracle", 150, |rng| {
        let k = rng.range(1, 6);
        let dim = rng.range(2, 9);
        // n = 0 and n = 1 in range; capacity 0 and >= n in range
        let n = rng.range(0, 33);
        let capacity = rng.range(0, n + 4);
        let mut xs: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        // duplicated vectors force duplicate scores (index tie-break);
        // non-finite vectors must be quarantined, never selected
        for i in 1..n {
            if rng.chance(0.2) {
                let src = rng.below(i);
                let (a, b) = xs.split_at_mut(i * dim);
                b[..dim].copy_from_slice(&a[src * dim..src * dim + dim]);
            }
        }
        if n > 0 && rng.chance(0.3) {
            let t = rng.below(n);
            xs[t * dim] = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY][rng.below(3)];
        }
        let km = SphericalKMeans::new(k, dim, 0.5, rng.next_u64());
        let got = km.top_capacity_tokens(&xs, n, capacity);
        assert_eq!(got.len(), k);

        // naive oracle: disjoint argmax buckets (first centroid wins
        // ties, non-finite vectors quarantined), each cluster keeping its
        // top-capacity members by (score desc, index asc), sorted asc
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); k];
        for i in 0..n {
            let x = &xs[i * dim..(i + 1) * dim];
            if x.iter().any(|v| !v.is_finite()) {
                continue;
            }
            let mut best = 0;
            let mut best_dot = f32::NEG_INFINITY;
            for c in 0..k {
                let d = dot(km.centroid(c), x);
                if d > best_dot {
                    best_dot = d;
                    best = c;
                }
            }
            buckets[best].push(i);
        }
        let mut seen = std::collections::HashSet::new();
        for (c, m) in got.iter().enumerate() {
            // bucket scores are finite (quarantine upstream), so the
            // plain total-order comparator is the selection order
            let mut scored: Vec<(f32, usize)> = buckets[c]
                .iter()
                .map(|&i| (dot(km.centroid(c), &xs[i * dim..(i + 1) * dim]), i))
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut expect: Vec<usize> =
                scored.into_iter().take(capacity).map(|(_, i)| i).collect();
            expect.sort_unstable();
            assert_eq!(m, &expect, "cluster {c} disagrees with the naive oracle");
            assert!(m.len() <= capacity, "cluster {c} over capacity");
            for &i in m {
                assert!(seen.insert(i), "token {i} selected by two clusters");
            }
        }

        // the capacity-bound invariant holds on every compile, and the
        // compiled rows agree with the membership-pair oracle
        let spec = km.expert_choice_spec(&xs, n, capacity);
        let p = spec.compile(n);
        assert!(p.is_causal() && p.rows_sorted());
        assert!(
            p.max_cluster_nnz() <= capacity * (capacity + 1) / 2,
            "per-cluster nnz {} over the capacity-{capacity} bound",
            p.max_cluster_nnz()
        );
        for i in 0..n {
            for j in 0..n {
                assert_eq!(p.allowed(i, j), oracle_allowed(&spec, n, i, j), "i={i} j={j}");
            }
        }
    });
}

#[test]
fn prop_threshold_matches_dense_score_oracle() {
    check("threshold_oracle", 150, |rng| {
        // n = 0 and n = 1 in range; scores include NaN/±inf poison that
        // must be quarantined (never admitted, never floor-topped)
        let n = rng.range(0, 25);
        let cut = (rng.normal() * 0.5) as f32;
        let floor = rng.range(0, n + 3);
        let mut scores: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        for s in scores.iter_mut() {
            if rng.chance(0.3) {
                *s = (*s).signum() * 0.5; // duplicate scores: index tie-break
            }
            if rng.chance(0.08) {
                *s = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY][rng.below(3)];
            }
        }
        let spec = AttentionSpec::threshold_from_scores(&scores, n, cut, floor).unwrap();
        let p = spec.compile(n);
        assert!(p.is_causal() && p.rows_sorted());
        for i in 0..n {
            // dense oracle: the finite causal scores sorted (desc, index
            // asc); admit those >= cut, then top up to the floor
            let mut fin: Vec<(f32, usize)> = (0..=i)
                .filter_map(|j| {
                    let s = scores[i * n + j];
                    s.is_finite().then_some((s, j))
                })
                .collect();
            fin.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let above = fin.iter().filter(|&&(s, _)| s >= cut).count();
            let keep = above.max(floor.min(fin.len()));
            let mut expect: Vec<usize> = fin[..keep].iter().map(|&(_, j)| j).collect();
            expect.sort_unstable();
            assert_eq!(p.row(i), &expect[..], "row {i} disagrees with the dense oracle");
            for &j in p.row(i) {
                assert!(scores[i * n + j].is_finite(), "non-finite score admitted");
            }
            assert!(p.row(i).len() >= floor.min(fin.len()), "floor not honored at row {i}");
        }
        // non-finite cuts and wrong-sized matrices are rejected
        assert!(AttentionSpec::threshold_from_scores(&scores, n, f32::NAN, 0).is_err());
        if n > 0 {
            assert!(AttentionSpec::threshold_from_scores(&scores[1..], n, cut, 0).is_err());
        }
    });
}

/// Naive reference oracle: the paper's definitions evaluated directly per
/// (i, j) pair, including composition — the semantics `compile` must match.
fn oracle_allowed(spec: &AttentionSpec, n: usize, i: usize, j: usize) -> bool {
    if j > i || i >= n || j >= n {
        return false;
    }
    match spec {
        AttentionSpec::Full => true,
        AttentionSpec::Local { window } => i - j < (*window).max(1),
        AttentionSpec::BlockLocal { window } => {
            let w = (*window).max(1);
            i / w - j / w <= 1
        }
        AttentionSpec::Strided { stride } => (i - j) % (*stride).max(1) == 0,
        AttentionSpec::Routing { clusters } => {
            clusters.iter().any(|m| m.contains(&i) && m.contains(&j))
        }
        // same membership-pair semantics as Routing, after the compile's
        // defensive normalization: filter to < n, sort, dedup, then clamp
        // to capacity (a no-op for constructor-built specs)
        AttentionSpec::ExpertChoice { clusters, capacity } => clusters.iter().any(|m| {
            let mut ms: Vec<usize> = m.iter().copied().filter(|&t| t < n).collect();
            ms.sort_unstable();
            ms.dedup();
            ms.truncate(*capacity);
            ms.contains(&i) && ms.contains(&j)
        }),
        AttentionSpec::Threshold { rows } => {
            rows.get(i).is_some_and(|r| r.contains(&j))
        }
        AttentionSpec::Union(parts) => parts.iter().any(|p| oracle_allowed(p, n, i, j)),
        AttentionSpec::Intersect(parts) => parts.iter().all(|p| oracle_allowed(p, n, i, j)),
    }
}

/// Random spec over positions < `bound`, with nested composition.
fn random_spec(rng: &mut Rng, bound: usize, depth: usize) -> AttentionSpec {
    let b = bound.max(2);
    match rng.below(if depth == 0 { 7 } else { 9 }) {
        0 => AttentionSpec::Full,
        1 => AttentionSpec::local(rng.range(1, b + 1)).unwrap(),
        2 => AttentionSpec::block_local(rng.range(1, b + 1)).unwrap(),
        3 => AttentionSpec::strided(rng.range(1, b + 1)).unwrap(),
        4 => {
            let k = rng.range(1, 5);
            let clusters: Vec<Vec<usize>> =
                (0..k).map(|_| (0..b).filter(|_| rng.chance(0.3)).collect()).collect();
            AttentionSpec::routing(clusters)
        }
        5 => {
            // capacity 0 and capacity >= cluster size are both in range
            let k = rng.range(1, 5);
            let capacity = rng.range(0, b + 2);
            let clusters: Vec<Vec<usize>> = (0..k)
                .map(|_| {
                    let mut m: Vec<usize> = (0..b).filter(|_| rng.chance(0.3)).collect();
                    m.truncate(capacity);
                    m
                })
                .collect();
            AttentionSpec::expert_choice(clusters, capacity).unwrap()
        }
        6 => {
            // per-row causal attend sets, possibly covering fewer rows
            // than the compile's n (missing rows compile empty)
            let rows: Vec<Vec<usize>> = (0..rng.range(0, b + 1))
                .map(|i| (0..=i).filter(|_| rng.chance(0.3)).collect())
                .collect();
            AttentionSpec::threshold(rows).unwrap()
        }
        op => {
            let parts: Vec<AttentionSpec> =
                (0..rng.range(1, 4)).map(|_| random_spec(rng, bound, depth - 1)).collect();
            if op == 7 {
                AttentionSpec::union(parts).unwrap()
            } else {
                AttentionSpec::intersect(parts).unwrap()
            }
        }
    }
}

#[test]
fn prop_compiled_pattern_matches_oracle() {
    check("compiled_oracle", 150, |rng| {
        // n = 0 and n = 1 are in range: the old code underflowed there
        let n = rng.range(0, 40);
        let spec = random_spec(rng, n, 2);
        let p = spec.compile(n);
        assert_eq!(p.n(), n);
        let mut total = 0usize;
        for i in 0..n {
            let row = p.row(i);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "rows strictly ascending");
            assert!(row.iter().all(|&j| j <= i), "causality");
            for j in 0..n {
                assert_eq!(
                    p.allowed(i, j),
                    oracle_allowed(&spec, n, i, j),
                    "disagrees with oracle at i={i} j={j} for {spec:?}"
                );
            }
            total += row.len();
        }
        assert_eq!(p.nnz(), total, "CSR nnz must equal the row-length sum");
        assert!(p.is_causal() && p.rows_sorted());
        assert!(p.density() <= 1.0 + 1e-12);
        // out-of-range queries are empty, never a panic
        assert_eq!(p.row(n), &[] as &[usize]);
        assert!(!p.allowed(n, 0));
    });
}

#[test]
fn prop_routing_pattern_causal_and_symmetric_membership() {
    check("routing_pattern", 100, |rng| {
        let n = rng.range(4, 48);
        let k = rng.range(1, 5);
        let clusters: Vec<Vec<usize>> =
            (0..k).map(|_| (0..n).filter(|_| rng.chance(0.3)).collect()).collect();
        let p = AttentionSpec::routing(clusters.clone()).compile(n);
        assert!(p.is_causal());
        for i in 0..n {
            for j in 0..=i {
                let expect = clusters.iter().any(|m| m.contains(&i) && m.contains(&j));
                assert_eq!(p.allowed(i, j), expect);
                // membership symmetry modulo causality
                if p.allowed(i, j) && j < i {
                    assert!(!p.allowed(j, i), "causality");
                }
            }
        }
    });
}

#[test]
fn prop_positional_kinds_attend_to_self() {
    check("pattern_diag", 60, |rng| {
        let n = rng.range(2, 40);
        let spec = match rng.below(3) {
            0 => AttentionSpec::local(rng.range(1, n + 1)).unwrap(),
            1 => AttentionSpec::strided(rng.range(1, n + 1)).unwrap(),
            _ => AttentionSpec::block_local(rng.range(1, n + 1)).unwrap(),
        };
        let p = spec.compile(n);
        assert!(p.density() <= 1.0 + 1e-12);
        // every token attends at least to itself for positional kinds
        for i in 0..n {
            assert!(p.allowed(i, i));
            assert_eq!(*p.row(i).last().unwrap(), i, "diagonal is the last entry");
        }
    });
}

#[test]
fn prop_spec_json_roundtrip() {
    check("spec_json", 80, |rng| {
        let spec = random_spec(rng, 16, 2);
        let text = spec.to_json().to_string();
        let back = AttentionSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec, "round-trip failed for {text}");
    });
}

#[test]
fn prop_complexity_routing_optimum_near_sqrt() {
    check("complexity_opt", 30, |rng| {
        let n = 1 << rng.range(8, 15);
        let d = 1 << rng.range(4, 8);
        let flops = |k: usize| {
            AttentionSpec::routing_balanced(n, k).unwrap().flops_estimate(n, d)
        };
        let kopt = optimal_clusters(n);
        let copt = flops(kopt);
        // cost function is convex-ish in k: both far extremes are worse
        assert!(copt <= flops((kopt / 8).max(1)) && copt <= flops(kopt * 8));
    });
}

#[test]
fn prop_union_nnz_bounds_and_intersect_subset() {
    check("compose_bounds", 80, |rng| {
        let n = rng.range(1, 32);
        let a = random_spec(rng, n, 1);
        let b = random_spec(rng, n, 1);
        let pa = a.compile(n);
        let pb = b.compile(n);
        let pu = AttentionSpec::union(vec![a.clone(), b.clone()]).unwrap().compile(n);
        let pi = AttentionSpec::intersect(vec![a, b]).unwrap().compile(n);
        assert!(pu.nnz() >= pa.nnz().max(pb.nnz()));
        assert!(pu.nnz() <= pa.nnz() + pb.nnz());
        assert!(pi.nnz() <= pa.nnz().min(pb.nnz()));
        // inclusion-exclusion pins the union size exactly
        assert_eq!(pu.nnz() + pi.nnz(), pa.nnz() + pb.nnz());
    });
}

// -------------------------------------------------------------- engine

#[test]
fn prop_sharded_pattern_partitions_rows_and_nnz() {
    check("sharded_nnz", 100, |rng| {
        // n = 0 and n < k are in range
        let n = rng.range(0, 48);
        let spec = random_spec(rng, n, 2);
        let pattern = std::sync::Arc::new(spec.compile(n));
        let k = rng.range(1, 9);
        for sharded in [
            ShardedPattern::by_rows(std::sync::Arc::clone(&pattern), k).unwrap(),
            ShardedPattern::balanced(std::sync::Arc::clone(&pattern), k).unwrap(),
        ] {
            let shards = sharded.shards();
            assert_eq!(shards.len(), k);
            let mut cursor = 0usize;
            let mut nnz = 0usize;
            for (s, shard) in shards.iter().enumerate() {
                assert_eq!(shard.index, s);
                assert_eq!(shard.rows.start, cursor, "shards must be contiguous");
                assert!(shard.rows.end >= shard.rows.start);
                cursor = shard.rows.end;
                let expect: usize = shard.rows.clone().map(|i| pattern.row(i).len()).sum();
                assert_eq!(shard.nnz, expect, "per-shard nnz must match its rows");
                assert_eq!(shard.cost(8), 2 * expect as u64 * 8);
                nnz += shard.nnz;
            }
            assert_eq!(cursor, n, "shards must cover every row exactly once");
            assert_eq!(nnz, pattern.nnz(), "shard nnz must sum to CompiledPattern::nnz()");
        }
    });
}

#[test]
fn prop_pattern_cache_equals_fresh_compile() {
    check("pattern_cache", 60, |rng| {
        let mut cache = PatternCache::new();
        let specs: Vec<(AttentionSpec, usize)> = (0..rng.range(1, 6))
            .map(|_| {
                let n = rng.range(0, 24);
                (random_spec(rng, n, 1), n)
            })
            .collect();
        for round in 0..3 {
            for (spec, n) in &specs {
                let cached = cache.get_or_compile(spec, *n);
                assert_eq!(*cached, spec.compile(*n), "cached must equal a fresh compile");
                if round > 0 {
                    // later rounds must be hits on the same shared compile
                    let again = cache.get_or_compile(spec, *n);
                    assert!(std::sync::Arc::ptr_eq(&cached, &again));
                }
            }
        }
        let s = cache.stats();
        assert_eq!(s.lookups(), s.hits + s.misses);
        assert!(s.misses as usize <= specs.len(), "at most one compile per distinct key");
        assert!(cache.len() as u64 == s.misses, "one cache entry per miss");
        assert!(s.hit_rate() <= 1.0);
    });
}

#[test]
fn prop_engine_sparse_attention_matches_dense_oracle() {
    check("engine_oracle", 60, |rng| {
        // n = 0 and n = 1 are in range; routing specs can leave rows
        // fully masked (unrouted tokens)
        let n = rng.range(0, 20);
        let d = rng.range(1, 9);
        let spec = random_spec(rng, n, 1);
        let pattern = spec.compile(n);
        let qkv: Vec<f32> = (0..3 * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(n * d);
        let (k, v) = rest.split_at(n * d);
        let sparse = sparse_attention(q, k, v, d, &pattern).unwrap();
        let dense = dense_masked_attention(q, k, v, d, &pattern).unwrap();
        assert_eq!(sparse.len(), n * d);
        for (a, b) in sparse.iter().zip(&dense) {
            assert!(a.is_finite(), "kernel must never emit NaN/inf");
            assert!((a - b).abs() < 1e-5, "sparse {a} vs dense oracle {b}");
        }
        // fully-masked rows are exactly zero (ties into the sampler's
        // fully-masked-logit guard: degenerate rows degrade, never poison)
        for i in 0..n {
            if pattern.row(i).is_empty() {
                assert!(sparse[i * d..(i + 1) * d].iter().all(|&x| x == 0.0));
            }
        }
        // sharded multi-worker evaluation agrees bitwise with single-shot
        let sharded = ShardedPattern::balanced(
            std::sync::Arc::new(pattern.clone()),
            rng.range(1, 5),
        )
        .unwrap();
        assert_outputs_match(
            &sparse,
            &sharded.attention(q, k, v, d).unwrap(),
            Exactness::Bitwise,
            "sharded vs single-shot",
        )
        .unwrap();
    });
}

#[test]
fn prop_batched_attention_bit_identical_to_sequential() {
    check("batched_attention", 60, |rng| {
        // B = 1, n = 0, and n = 1 are all in range; patterns are either
        // one shared compile or a mixed per-sequence set
        let b = rng.range(1, 5);
        let n = rng.range(0, 16);
        let d = rng.range(1, 7);
        let shared = rng.chance(0.3);
        let patterns: Vec<Arc<CompiledPattern>> = if shared {
            let p = Arc::new(random_spec(rng, n, 1).compile(n));
            vec![p; b]
        } else {
            (0..b).map(|_| Arc::new(random_spec(rng, n, 1).compile(n))).collect()
        };
        let qkv: Vec<f32> = (0..3 * b * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(b * n * d);
        let (k, v) = rest.split_at(b * n * d);
        let workers = rng.range(1, 6);
        let batch = BatchedAttention::new(patterns.clone(), workers).unwrap();
        assert_eq!(batch.batch(), b);
        assert_eq!(batch.nnz(), patterns.iter().map(|p| p.nnz()).sum::<usize>());
        assert_eq!(batch.worker_rows().iter().sum::<usize>(), b * n);
        let out = batch.attention(q, k, v, d).unwrap();
        let mut expect = Vec::with_capacity(b * n * d);
        for (s, p) in patterns.iter().enumerate() {
            let lo = s * n * d;
            let hi = lo + n * d;
            expect.extend(sparse_attention(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, p).unwrap());
        }
        assert_outputs_match(
            &expect,
            &out,
            Exactness::Bitwise,
            "batched must be bit-identical to B independent calls",
        )
        .unwrap();
        // the one-shot form plans identically
        assert_eq!(sparse_attention_batch(q, k, v, d, &patterns, workers).unwrap(), expect);
    });
}

#[test]
fn prop_epoch_cache_never_serves_stale_and_counts_evictions() {
    check("epoch_cache", 60, |rng| {
        let n = rng.range(1, 24);
        let n_slots = rng.range(1, 4);
        let mut cache = EpochCache::new();
        // per-slot current (epoch, memberships); cluster 0 carries a
        // slot-unique tag so specs never collide across slots, which
        // keeps the eviction accounting exact
        let fresh_spec = |rng: &mut Rng, si: usize| {
            let mut clusters: Vec<Vec<usize>> = vec![vec![1000 + si]];
            clusters
                .extend((0..rng.range(1, 4)).map(|_| (0..n).filter(|_| rng.chance(0.3)).collect()));
            AttentionSpec::routing(clusters)
        };
        let mut current: Vec<(u64, AttentionSpec)> =
            (0..n_slots).map(|si| (0, fresh_spec(rng, si))).collect();
        let static_spec = AttentionSpec::local(rng.range(1, n + 1)).unwrap();
        let pinned = cache.get_static(&static_spec, n);
        let mut expected_evictions = 0u64;
        let mut seen: Vec<bool> = vec![false; n_slots];
        for _round in 0..rng.range(2, 6) {
            for si in 0..n_slots {
                let slot = RouteSlot { layer: 0, head: si, seq: 0 };
                if rng.chance(0.5) {
                    // epoch bump: the slot's memberships are superseded
                    current[si].0 += 1;
                    current[si].1 = fresh_spec(rng, si);
                    if seen[si] {
                        expected_evictions += 1;
                    }
                }
                let (epoch, spec) = current[si].clone();
                let p = cache.get_routed(slot, epoch, n, || spec.clone());
                seen[si] = true;
                assert_eq!(
                    *p,
                    spec.compile(n),
                    "cache must never serve a previous epoch's memberships"
                );
                assert_eq!(cache.slot_epoch(slot), Some(epoch));
                // a same-epoch re-fetch is a hit on the same shared compile
                let again =
                    cache.get_routed(slot, epoch, n, || panic!("hit must not regenerate"));
                assert!(Arc::ptr_eq(&p, &again));
                assert_eq!(cache.stats().evictions, expected_evictions);
            }
        }
        // static compiles survive arbitrary routing churn
        assert!(Arc::ptr_eq(&pinned, &cache.get_static(&static_spec, n)));
        // bounded: the pinned static entry + at most one live per slot
        assert!(cache.len() <= 1 + n_slots, "stale compiles must not accumulate");
        let es = cache.epoch_stats();
        assert_eq!(es.lookups(), es.epoch_hits + es.epoch_misses);
        assert!(es.hit_rate() <= 1.0);
    });
}

#[test]
fn prop_compile_band_equals_monolithic_row_slices() {
    check("compile_band", 150, |rng| {
        // n = 0 and n = 1 in range; band endpoints deliberately overshoot
        // n to exercise the clamping contract, and may be empty
        let n = rng.range(0, 40);
        let spec = random_spec(rng, n, 2);
        let p = spec.compile(n);
        let a = rng.range(0, n + 8);
        let b = rng.range(0, n + 8);
        let (raw_lo, raw_hi) = (a.min(b), a.max(b));
        let band = spec.compile_band(n, raw_lo..raw_hi);
        let (lo, hi) = (raw_lo.min(n), raw_hi.min(n));
        assert_eq!((band.start(), band.end()), (lo, hi), "band range clamps to 0..n");
        assert_eq!(band.len(), hi - lo);
        assert_eq!(band.is_empty(), lo == hi);
        let mut nnz = 0usize;
        for i in 0..n + 2 {
            if (lo..hi).contains(&i) {
                assert_eq!(band.row(i), p.row(i), "band row {i} != monolithic slice");
                assert_eq!(band.row_clusters(i), p.row_clusters(i), "cluster ids at row {i}");
                nnz += p.row(i).len();
            } else {
                assert!(band.row(i).is_empty(), "row {i} outside the band must be empty");
            }
        }
        assert_eq!(band.nnz(), nnz, "band nnz must equal the covered rows' sum");
        // the padded n-row pattern agrees row-for-row: in-band rows are the
        // monolithic slices, out-of-band rows are empty
        let padded = band.to_pattern();
        assert_eq!(padded.n(), n);
        for i in 0..n {
            if (lo..hi).contains(&i) {
                assert_eq!(padded.row(i), p.row(i));
                assert_eq!(padded.row_clusters(i), p.row_clusters(i));
            } else {
                assert!(padded.row(i).is_empty());
            }
        }
        // deterministic BlockLocal straddle: split a compile at a
        // non-block-aligned row, so one band boundary lands strictly
        // inside a block — both halves must still tile the monolith
        if n >= 2 {
            let w = rng.range(1, n);
            let bl = AttentionSpec::block_local(w).unwrap();
            let pb = bl.compile(n);
            let mid = (w + 1).min(n - 1); // first row of block 1, minus alignment
            for range in [0..mid, mid..n] {
                let half = bl.compile_band(n, range.clone());
                for i in range {
                    assert_eq!(half.row(i), pb.row(i), "BlockLocal straddle row {i}");
                }
            }
        }
    });
}

#[test]
fn prop_chunked_pattern_budgeted_equals_monolithic() {
    check("chunked_budgeted", 80, |rng| {
        // tiny budgets (including 0 bytes) force constant spilling; the
        // streamed result must stay bit-identical to the monolith anyway
        let n = rng.range(0, 28);
        let d = rng.range(1, 7);
        let spec = random_spec(rng, n, 1);
        let p = spec.compile(n);
        let budget = MemoryBudget::bytes(rng.range(0, 2048));
        let band_rows = rng.range(0, 9); // 0 clamps to 1
        let mut chunked = ChunkedPattern::new(spec.clone(), n, band_rows, budget.clone());
        assert_eq!(chunked.nnz(), p.nnz());
        assert_eq!(chunked.cost(d), p.cost(d));
        for i in 0..n + 2 {
            assert_eq!(chunked.row(i), p.row(i), "chunked row {i} != monolithic");
        }
        let lo = rng.range(0, n + 2).min(n);
        let hi = rng.range(lo, n + 2);
        let gathered: Vec<(usize, Vec<usize>, Vec<u32>)> =
            chunked.rows(lo..hi).map(|(i, r, c)| (i, r.to_vec(), c.to_vec())).collect();
        for (i, r, c) in &gathered {
            assert_eq!((r.as_slice(), c.as_slice()), (p.row(*i), p.row_clusters(*i)));
        }
        assert_eq!(gathered.len(), hi.min(n) - lo);
        assert_eq!(chunked.assemble(), p, "assembled bands must equal the monolithic compile");
        // streamed banded attention is bit-identical to the unbudgeted path
        let qkv: Vec<f32> = (0..3 * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(n * d);
        let (k, v) = rest.split_at(n * d);
        let banded = chunked.attention_backend(q, k, v, d, &Reference).unwrap();
        assert_outputs_match(
            &sparse_attention(q, k, v, d, &p).unwrap(),
            &banded,
            Exactness::Bitwise,
            "banded vs monolithic",
        )
        .unwrap();
        // the shared meter tracks residency exactly, and drop returns it
        assert_eq!(budget.resident(), chunked.resident_bytes());
        drop(chunked);
        assert_eq!(budget.resident(), 0, "drop must release every charged byte");
    });
}

#[test]
fn prop_ulps_zero_equals_bitwise_on_finite() {
    // Exactness::Ulps(0) must accept exactly what Bitwise accepts on
    // nonzero finite values (±0.0 is the documented carve-out: 0 ulps
    // apart but bitwise-distinct)
    check("ulps_zero_bitwise", 200, |rng| {
        let mut draw = |rng: &mut Rng| loop {
            let x = (rng.normal() * 10f64.powi(rng.range(0, 7) as i32 - 3)) as f32;
            if x != 0.0 && x.is_finite() {
                return x;
            }
        };
        let a = draw(rng);
        // sometimes identical, sometimes a near-neighbor, sometimes far
        let b = match rng.below(3) {
            0 => a,
            1 => f32::from_bits(a.to_bits().wrapping_add(rng.range(0, 3) as u32)),
            _ => draw(rng),
        };
        for (x, y) in [(a, b), (b, a)] {
            if !(x != 0.0 && y != 0.0 && x.is_finite() && y.is_finite()) {
                continue; // the bit-neighbor draw can land on inf
            }
            assert_eq!(
                values_match(x, y, Exactness::Ulps(0)),
                values_match(x, y, Exactness::Bitwise),
                "Ulps(0) vs Bitwise disagree on {x:?} ({:#010x}) vs {y:?} ({:#010x})",
                x.to_bits(),
                y.to_bits()
            );
            assert_eq!(ulps_distance(x, y), ulps_distance(y, x), "distance is symmetric");
        }
        assert_eq!(ulps_distance(a, a), 0);
    });
}

#[test]
fn prop_simd_backend_within_declared_ulps() {
    // the fast-math kernel honors its declared contract on arbitrary
    // random patterns — including fully-masked rows and lane remainders
    check("simd_declared_ulps", 80, |rng| {
        let n = rng.range(0, 24);
        let d = rng.range(1, 20); // crosses the 8-lane chunk boundary
        let spec = random_spec(rng, n, 1);
        let pattern = spec.compile(n);
        let qkv: Vec<f32> = (0..3 * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(n * d);
        let (k, v) = rest.split_at(n * d);
        let oracle = Reference.attention(q, k, v, d, &pattern).unwrap();
        let fast = Simd.attention(q, k, v, d, &pattern).unwrap();
        assert_outputs_match(&oracle, &fast, Simd.exactness(), "Simd vs Reference")
            .unwrap_or_else(|e| panic!("n={n} d={d} spec={spec:?}: {e}"));
        assert!(fast.iter().all(|x| x.is_finite()), "fast math must not emit NaN/inf");
        // fully-masked rows stay exactly zero under fast math too
        for i in 0..n {
            if pattern.row(i).is_empty() {
                assert!(fast[i * d..(i + 1) * d].iter().all(|&x| x == 0.0));
            }
        }
    });
}

// ------------------------------------------------------------- k-means

#[test]
fn prop_kmeans_update_preserves_unit_norm() {
    check("kmeans_norm", 100, |rng| {
        let k = rng.range(1, 6);
        let dim = rng.range(2, 12);
        let n = rng.range(1, 64);
        let mut km = SphericalKMeans::new(k, dim, rng.f32().clamp(0.01, 0.99), rng.next_u64());
        let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        km.update(&xs, n);
        for c in 0..k {
            let nn = norm(km.centroid(c));
            assert!((nn - 1.0).abs() < 1e-3, "norm {nn}");
        }
    });
}

#[test]
fn prop_kmeans_assign_is_argmax() {
    check("kmeans_assign", 100, |rng| {
        let k = rng.range(1, 8);
        let dim = rng.range(2, 12);
        let km = SphericalKMeans::new(k, dim, 0.5, rng.next_u64());
        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let a = km.assign(&x);
        let scores = km.scores(&x);
        for (c, &s) in scores.iter().enumerate() {
            assert!(s <= scores[a] + 1e-6, "cluster {c} beats assigned {a}");
        }
    });
}

// ------------------------------------------------------------- batcher

#[test]
fn prop_batcher_no_token_lost_or_duplicated() {
    struct Counter {
        next: i32,
    }
    impl TokenSource for Counter {
        fn vocab(&self) -> usize {
            1 << 30
        }
        fn fill(&mut self, out: &mut [i32]) {
            for t in out.iter_mut() {
                *t = self.next;
                self.next += 1;
            }
        }
    }
    check("batcher_conservation", 60, |rng| {
        let b = rng.range(1, 5);
        let s = rng.range(1, 5);
        let t = rng.range(1, 33);
        let lanes: Vec<Box<dyn TokenSource>> = (0..b)
            .map(|i| Box::new(Counter { next: (i as i32) << 20 }) as Box<dyn TokenSource>)
            .collect();
        let mut batcher = routing_transformer::data::BlockBatcher::new(lanes, s, t);
        let blocks = rng.range(1, 4);
        let mut per_lane: Vec<Vec<i32>> = vec![Vec::new(); b];
        for _ in 0..blocks {
            let blk = batcher.next_block();
            for si in 0..s {
                for bi in 0..b {
                    let off = (si * b + bi) * t;
                    per_lane[bi].extend_from_slice(&blk.tokens[off..off + t]);
                }
            }
        }
        for (bi, lane) in per_lane.iter().enumerate() {
            let base = (bi as i32) << 20;
            let expect: Vec<i32> = (0..lane.len() as i32).map(|i| base + i).collect();
            assert_eq!(lane, &expect, "lane {bi} must be contiguous");
        }
    });
}

#[test]
fn prop_data_sources_deterministic_and_in_vocab() {
    check("data_sources", 24, |rng| {
        let seed = rng.next_u64();
        for name in ["zipf", "needle", "bytes", "images"] {
            let vocab = if name == "needle" { 512 } else { 256 };
            let mk = || data::source_by_name(name, vocab, 256, 32, seed).unwrap();
            let mut a = mk();
            let mut b = mk();
            let ta = data::take(a.as_mut(), 512);
            let tb = data::take(b.as_mut(), 512);
            assert_eq!(ta, tb, "{name} must be deterministic");
            assert!(ta.iter().all(|&t| (t as usize) < vocab), "{name} in vocab");
        }
    });
}

// ------------------------------------------------------------ sampler

#[test]
fn prop_nucleus_probs_normalized_with_correct_support() {
    check("nucleus", 150, |rng| {
        let v = rng.range(2, 200);
        let logits: Vec<f32> = (0..v).map(|_| (rng.normal() * 3.0) as f32).collect();
        let top_p = 0.1 + rng.f32() * 0.9;
        let cfg = SamplerConfig { temperature: 0.2 + rng.f32() * 2.0, top_p };
        let probs = nucleus_probs(&logits, cfg);
        let mass: f64 = probs.iter().sum();
        // the top-p cut renormalizes over the kept support
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
        assert!(probs.iter().all(|&p| p >= 0.0 && p.is_finite()));
        // the argmax logit always stays in the nucleus
        let argmax = (0..v).max_by(|&a, &b| logits[a].partial_cmp(&logits[b]).unwrap()).unwrap();
        assert!(probs[argmax] > 0.0, "argmax dropped from nucleus");
        // sampling only returns support members
        let mut srng = Rng::new(rng.next_u64());
        for _ in 0..20 {
            let t = sample_logits(&logits, cfg, &mut srng);
            assert!(probs[t] > 0.0, "sampled outside nucleus");
        }
    });
}

// ---------------------------------------------------------- schedules

#[cfg(feature = "xla")]
#[test]
fn prop_schedules_finite_positive_and_warmup_monotone() {
    check("schedules", 100, |rng| {
        let warmup = rng.range(1, 1000) as u32;
        let scale = 0.001 + rng.f32() * 10.0;
        for sched in [
            LrSchedule::Constant { lr: scale },
            LrSchedule::InverseSqrt { scale, warmup },
            LrSchedule::RsqrtDecay { lr: scale, warmup },
        ] {
            let mut prev = 0.0f32;
            for step in 1..=warmup {
                let lr = sched.lr(step);
                assert!(lr.is_finite() && lr >= 0.0);
                if !matches!(sched, LrSchedule::Constant { .. }) {
                    assert!(lr >= prev - 1e-9, "warmup must be non-decreasing");
                }
                prev = lr;
            }
            // decay: far beyond warmup the lr is <= peak
            let peak = sched.lr(warmup);
            assert!(sched.lr(warmup * 100 + 1) <= peak + 1e-9);
        }
    });
}

// --------------------------------------------------------- tokenizers

#[test]
fn prop_byte_tokenizer_roundtrip() {
    check("byte_roundtrip", 100, |rng| {
        let len = rng.range(0, 200);
        let s: String = (0..len).map(|_| rng.range(32, 127) as u8 as char).collect();
        let t = ByteTokenizer;
        assert_eq!(t.decode(&t.encode(&s)), s);
    });
}

#[test]
fn prop_word_vocab_roundtrip_known_words() {
    check("word_roundtrip", 60, |rng| {
        let lexicon = ["alpha", "beta", "gamma", "delta", "eps"];
        let n = rng.range(5, 60);
        let corpus: Vec<&str> = (0..n).map(|_| lexicon[rng.below(lexicon.len())]).collect();
        let text = corpus.join(" ");
        let v = WordVocab::build(&text, 100);
        assert_eq!(v.decode(&v.encode(&text)), text);
        assert!((v.coverage(&text) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_bpe_roundtrip_on_training_domain() {
    check("bpe_roundtrip", 20, |rng| {
        let words = ["rout", "ing", "trans", "form", "er", " "];
        let corpus: String = (0..400).map(|_| words[rng.below(words.len())]).collect();
        let bpe = Bpe::train(corpus.as_bytes(), 256 + rng.range(1, 64));
        let sample: String = (0..50).map(|_| words[rng.below(words.len())]).collect();
        assert_eq!(bpe.decode(&bpe.encode(&sample)), sample);
        assert!(bpe.encode(&sample).len() <= sample.len());
    });
}

// --------------------------------------------------------------- misc

#[test]
fn prop_jsd_bounds_and_symmetry() {
    check("jsd", 150, |rng| {
        let n = rng.range(2, 64);
        let mk = |rng: &mut Rng| {
            let mut v: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = v.iter().sum();
            v.iter_mut().for_each(|x| *x /= s);
            v
        };
        let p = mk(rng);
        let q = mk(rng);
        let d = jsd(&p, &q);
        assert!((0.0..=JSD_MAX + 1e-9).contains(&d));
        assert!((d - jsd(&q, &p)).abs() < 1e-12);
        assert!(jsd(&p, &p) < 1e-12);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0).round()),
            3 => {
                let len = rng.range(0, 12);
                Json::Str((0..len).map(|_| rng.range(32, 127) as u8 as char).collect())
            }
            4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json_roundtrip", 150, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "roundtrip failed for {text}");
    });
}

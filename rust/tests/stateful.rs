//! Stateful model-based tests for the serving engine (à la
//! proptest-stateful): random op sequences drive the real
//! `RoutingSession` + `EpochCache` + `WorkerPool` stack against a naive
//! reference model, checking after every op that
//!
//! * the served pattern always matches a fresh compile of the spec
//!   current at the slot's assignment epoch,
//! * every hit/miss/eviction/unchanged-epoch counter matches the model's
//!   independent bookkeeping,
//! * epochs, assignment epochs, and dirty sets evolve exactly as the
//!   model predicts from a before/after `assign()` oracle,
//! * pool execution is bit-identical to the inline single-thread path
//!   (and survives induced worker panics without hanging or poisoning),
//! * every execution backend honors its declared `Exactness` contract
//!   across random batches — {Reference, Blocked} plus registry lookups
//!   stay bit-identical, the fast-math `Simd` backend stays within its
//!   declared ulps budget of Reference (and bitwise against itself
//!   across execution strategies) — including n = 0, n = 1, and
//!   fully-masked rows, and
//! * incremental (dirty-cluster-only) spec regeneration equals a
//!   from-scratch `routing_spec`, with regen counters matching a
//!   touched-cluster model exactly, and
//! * the serve-layer `Scheduler` (admission control, FIFO slot grants,
//!   deadline sheds, retirement GC) agrees with a naive mirror on every
//!   step's batch, every outcome, and every counter — including the
//!   `EpochCache` evictions its retirement GC fires, and
//! * a request whose step's attention runs through a
//!   `Coordinator<SimTransport>` with workers crashing mid-step still
//!   resolves exactly once, bit-identical to the inline reference, with
//!   the coordinator's grant ledger conserved throughout, and
//! * the byte-budgeted `EpochCache` agrees with a naive mirror of the
//!   documented spill policy: inserts charge the shared `MemoryBudget`
//!   and spill least-recently-used routed slots in deterministic tick
//!   order — never the just-touched slot, never entries touched since
//!   `mark_step()`, never pinned statics — and resident bytes exceed the
//!   budget only while everything left is protected (the soft cap).
//!
//! The offline environment ships no `proptest`, so this reuses the
//! hand-rolled seeded-case harness from `tests/common/mod.rs`: every
//! property runs ≥ 64 seeded random cases, replays the shrink seeds
//! checked in under `proptest-regressions/stateful.txt` first, and
//! reports (and persists) the failing seed.

mod common;

use std::cell::Cell;
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};
use std::sync::Arc;

use routing_transformer::attention::{
    assert_outputs_match, sparse_attention, AttentionSpec, Backend, BatchEntry, BatchedAttention,
    Blocked, CompiledPattern, Coordinator, CoordinatorConfig, EpochCache, Exactness, Execution,
    MemberCache, MemoryBudget, OutcomeKind, Reference, RequestOutcome, Retired, RouteSlot,
    RoutingSession, Scheduler, ServeRequest, ServeStats, ShardedPattern, Simd, SimTransport,
    SpecFamily, Submission, WorkerPool, WorkerState,
};
use routing_transformer::kmeans::SphericalKMeans;
use routing_transformer::util::rng::Rng;

/// Shrink seeds persisted from previous failures; replayed before the sweep.
const REGRESSIONS: &str = include_str!("../proptest-regressions/stateful.txt");

/// Run `f` over the recorded regression seeds, then `n` fresh seeded
/// cases; panic with the failing seed (persisting new failures).
fn check<F: Fn(&mut Rng)>(name: &str, n: usize, f: F) {
    common::check_with_regressions("stateful", REGRESSIONS, name, n, 0x57A7_0000, f);
}

// ------------------------------------------------------ reference model

const LAYERS: usize = 2;
const HEADS: usize = 2;
const SEQS: usize = 2;
const DIM: usize = 3;

/// Reference mirror of one (layer, head) routing slot: an independent
/// k-means copy plus naive epoch/dirty bookkeeping.
struct ModelSlot {
    km: SphericalKMeans,
    epoch: u64,
    assignment_epoch: u64,
    dirty: BTreeSet<usize>,
}

/// Reference mirror of one cached (layer, head, seq) entry.
struct ModelEntry {
    assignment_epoch: u64,
    epoch: u64,
    n: usize,
    spec: AttentionSpec,
}

#[derive(Default)]
struct ModelCounters {
    hits: u64,
    misses: u64,
    evictions: u64,
    epoch_hits: u64,
    epoch_misses: u64,
    unchanged_epochs: u64,
}

struct Model {
    slots: Vec<ModelSlot>,
    entries: HashMap<(usize, usize, usize), ModelEntry>,
    statics: HashSet<(AttentionSpec, usize)>,
    counters: ModelCounters,
}

impl Model {
    /// Mirror a fresh session: clone each slot's initial k-means state
    /// through the public getter, so the model evolves independently.
    fn mirror(session: &RoutingSession) -> Model {
        let slots = (0..LAYERS)
            .flat_map(|l| (0..HEADS).map(move |h| (l, h)))
            .map(|(l, h)| ModelSlot {
                km: session.kmeans(l, h).clone(),
                epoch: 0,
                assignment_epoch: 0,
                dirty: BTreeSet::new(),
            })
            .collect();
        Model {
            slots,
            entries: HashMap::new(),
            statics: HashSet::new(),
            counters: ModelCounters::default(),
        }
    }

    fn slot(&mut self, layer: usize, head: usize) -> &mut ModelSlot {
        &mut self.slots[layer * HEADS + head]
    }
}

/// Check every SUT counter and every slot's epoch state against the model.
fn assert_model_agrees(session: &RoutingSession, cache: &EpochCache, model: &Model) {
    let cs = cache.stats();
    assert_eq!(cs.hits, model.counters.hits, "compile-level hits");
    assert_eq!(cs.misses, model.counters.misses, "compile-level misses");
    assert_eq!(cs.evictions, model.counters.evictions, "evictions");
    let es = cache.epoch_stats();
    assert_eq!(es.epoch_hits, model.counters.epoch_hits, "epoch hits");
    assert_eq!(es.epoch_misses, model.counters.epoch_misses, "epoch misses");
    assert_eq!(es.unchanged_epochs, model.counters.unchanged_epochs, "unchanged epochs");
    assert_eq!(
        cache.len(),
        model.statics.len() + model.entries.len(),
        "live compiles: pinned statics + one per routed slot"
    );
    for l in 0..LAYERS {
        for h in 0..HEADS {
            let m = &model.slots[l * HEADS + h];
            assert_eq!(session.epoch(l, h), m.epoch, "cluster epoch of ({l}, {h})");
            assert_eq!(
                session.assignment_epoch(l, h),
                m.assignment_epoch,
                "assignment epoch of ({l}, {h})"
            );
            assert_eq!(
                session.dirty_tokens(l, h),
                m.dirty.iter().copied().collect::<Vec<_>>(),
                "dirty set of ({l}, {h})"
            );
        }
    }
}

fn random_xs(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n * DIM).map(|_| rng.normal() as f32).collect()
}

/// A small random spec for batch/static ops (possibly all-masked).
fn small_spec(rng: &mut Rng, n: usize) -> AttentionSpec {
    match rng.below(4) {
        0 => AttentionSpec::Full,
        1 => AttentionSpec::local(rng.range(1, n.max(1) + 1)).unwrap(),
        2 => AttentionSpec::strided(rng.range(1, n.max(1) + 1)).unwrap(),
        _ => {
            let clusters: Vec<Vec<usize>> = (0..rng.range(0, 3))
                .map(|_| (0..n).filter(|_| rng.chance(0.4)).collect())
                .collect();
            AttentionSpec::routing(clusters)
        }
    }
}

// --------------------------------------------------------- property 1

#[test]
fn prop_stateful_session_and_cache_match_reference_model() {
    check("session_cache_model", 64, |rng| {
        let seed = rng.next_u64();
        let k = rng.range(1, 4);
        let mut session = RoutingSession::new(LAYERS, HEADS, k, DIM, 0.3, seed).unwrap();
        let mut cache = EpochCache::new();
        let mut model = Model::mirror(&session);
        let static_pool = [
            AttentionSpec::Full,
            AttentionSpec::local(2).unwrap(),
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::strided(2).unwrap(),
        ];
        for _op in 0..rng.range(12, 25) {
            match rng.below(12) {
                // Update{layer, head}: one online k-means step, mirrored
                // independently; n = 0 and NaN-poisoned batches included
                0..=3 => {
                    let (layer, head) = (rng.below(LAYERS), rng.below(HEADS));
                    let n = rng.range(0, 10);
                    let mut xs = random_xs(rng, n);
                    if n > 0 && rng.chance(0.15) {
                        xs[rng.below(n * DIM)] = f32::NAN;
                    }
                    // naive oracle: assignments before vs after, via the
                    // public assign() on an independent k-means copy
                    let m = model.slot(layer, head);
                    let before = m.km.clone();
                    m.km.update(&xs, n);
                    let mut moved = Vec::new();
                    for i in 0..n {
                        let x = &xs[i * DIM..(i + 1) * DIM];
                        if x.iter().any(|v| !v.is_finite()) {
                            continue;
                        }
                        let (old, new) = (before.assign(x), m.km.assign(x));
                        if old != new {
                            moved.push((i, old, new));
                        }
                    }
                    if n > 0 {
                        m.epoch += 1;
                        if !moved.is_empty() {
                            m.assignment_epoch = m.epoch;
                            m.dirty.extend(moved.iter().map(|&(t, _, _)| t));
                        }
                    }
                    let upd = session.update(layer, head, &xs, n);
                    assert_eq!(upd.delta.moved, moved, "delta must match the assign() oracle");
                    let m = model.slot(layer, head);
                    assert_eq!(upd.epoch, m.epoch);
                    assert_eq!(upd.assignment_epoch, m.assignment_epoch);
                    assert_eq!(
                        session.kmeans(layer, head).centroids,
                        m.km.centroids,
                        "mirrored k-means must stay bitwise in lockstep"
                    );
                }
                // GetRouted{layer, head, seq}
                4..=7 => {
                    let (layer, head) = (rng.below(LAYERS), rng.below(HEADS));
                    let seq = rng.below(SEQS);
                    let slot = RouteSlot { layer, head, seq };
                    let n = rng.range(1, 9);
                    let w = rng.range(1, n + 1);
                    let xs = random_xs(rng, n);
                    let epoch = session.epoch(layer, head);
                    let ae = session.assignment_epoch(layer, head);
                    let key = (layer, head, seq);
                    let expect_hit = model
                        .entries
                        .get(&key)
                        .is_some_and(|e| e.assignment_epoch == ae && e.n == n);
                    let regenerated = Cell::new(false);
                    let p = cache.get_routed_at(slot, epoch, ae, n, || {
                        regenerated.set(true);
                        session.routing_spec(layer, head, &xs, n, w)
                    });
                    assert_eq!(
                        regenerated.get(),
                        !expect_hit,
                        "spec regeneration exactly on model-predicted misses"
                    );
                    if expect_hit {
                        let e = model.entries.get_mut(&key).unwrap();
                        if e.epoch != epoch {
                            e.epoch = epoch;
                            model.counters.unchanged_epochs += 1;
                        }
                        model.counters.epoch_hits += 1;
                        model.counters.hits += 1;
                        assert_eq!(
                            *p,
                            e.spec.compile(n),
                            "served pattern must match the spec stored at its assignment epoch"
                        );
                    } else {
                        if model.entries.remove(&key).is_some() {
                            model.counters.evictions += 1;
                        }
                        model.counters.epoch_misses += 1;
                        model.counters.misses += 1;
                        let spec =
                            model.slots[layer * HEADS + head].km.routing_spec(&xs, n, w);
                        assert_eq!(
                            *p,
                            spec.compile(n),
                            "miss must serve a fresh compile at the current assignments"
                        );
                        model.entries.insert(
                            key,
                            ModelEntry { assignment_epoch: ae, epoch, n, spec },
                        );
                    }
                    assert_eq!(cache.slot_assignment_epoch(slot), Some(ae));
                }
                // GetStatic
                8..=9 => {
                    let spec = static_pool[rng.below(static_pool.len())].clone();
                    let n = rng.range(1, 10);
                    let fresh = model.statics.insert((spec.clone(), n));
                    if fresh {
                        model.counters.misses += 1;
                    } else {
                        model.counters.hits += 1;
                    }
                    let p = cache.get_static(&spec, n);
                    assert_eq!(*p, spec.compile(n), "static compile must be exact");
                }
                // EvictSlot
                10 => {
                    let slot = RouteSlot {
                        layer: rng.below(LAYERS),
                        head: rng.below(HEADS),
                        seq: rng.below(SEQS),
                    };
                    let present =
                        model.entries.remove(&(slot.layer, slot.head, slot.seq)).is_some();
                    if present {
                        model.counters.evictions += 1;
                    }
                    assert_eq!(cache.evict_slot(slot).is_some(), present, "evict_slot presence");
                }
                // Clear (session state survives, cache resets fully)
                _ => {
                    cache.clear();
                    model.entries.clear();
                    model.statics.clear();
                    model.counters = ModelCounters::default();
                }
            }
            assert_model_agrees(&session, &cache, &model);
        }
    });
}

// --------------------------------------------------------- property 2

#[test]
fn prop_pool_and_scoped_match_inline_bitwise() {
    check("pool_matches_inline", 96, |rng| {
        let b = rng.range(1, 4);
        let n = rng.range(0, 10);
        let d = rng.range(1, 5);
        let shared = rng.chance(0.3);
        let patterns: Vec<Arc<CompiledPattern>> = if shared {
            vec![Arc::new(small_spec(rng, n).compile(n)); b]
        } else {
            (0..b).map(|_| Arc::new(small_spec(rng, n).compile(n))).collect()
        };
        let qkv: Vec<f32> = (0..3 * b * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(b * n * d);
        let (k, v) = rest.split_at(b * n * d);
        let workers = rng.range(1, 6);
        let batch = BatchedAttention::new(patterns.clone(), workers).unwrap();
        let inline = batch.attention_with(q, k, v, d, Execution::Inline).unwrap();
        // the global pool, a local pool (possibly zero-worker), and the
        // scoped baseline must all be bit-identical to inline
        let local_pool = WorkerPool::with_workers(rng.range(0, 4));
        for exec in [
            Execution::default(),
            Execution::Pool(&local_pool),
            Execution::Scoped,
        ] {
            assert_eq!(
                batch.attention_with(q, k, v, d, exec).unwrap(),
                inline,
                "{exec:?} diverged at b={b} n={n} d={d} workers={workers}"
            );
        }
        // and inline itself equals B independent kernel calls
        let mut expect = Vec::with_capacity(b * n * d);
        for (s, p) in patterns.iter().enumerate() {
            let lo = s * n * d;
            let hi = lo + n * d;
            expect.extend(sparse_attention(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, p).unwrap());
        }
        assert_eq!(inline, expect);
        // sharded single-sequence path agrees across executions too
        if n > 0 {
            let sharded =
                ShardedPattern::balanced(Arc::clone(&patterns[0]), rng.range(1, 5)).unwrap();
            let lo = 0;
            let hi = n * d;
            let base = sharded
                .attention_with(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, Execution::Inline)
                .unwrap();
            for exec in [Execution::default(), Execution::Pool(&local_pool), Execution::Scoped]
            {
                assert_eq!(
                    sharded.attention_with(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, exec).unwrap(),
                    base
                );
            }
        }
    });
}

// --------------------------------------------------------- property 3

#[test]
fn prop_pool_survives_induced_panics() {
    check("pool_panic_containment", 64, |rng| {
        let pool = WorkerPool::with_workers(rng.range(0, 4));
        let rounds = rng.range(1, 4);
        for _round in 0..rounds {
            let m = rng.range(2, 7);
            let per = rng.range(1, 5);
            let panic_at = rng.below(m);
            let as_error = rng.chance(0.3);
            let mut out = vec![0f32; m * per];
            let work: Vec<(usize, &mut [f32])> =
                out.chunks_mut(per).take(m).enumerate().collect();
            let result = pool.run(work, |i, slice| {
                if i == panic_at {
                    if as_error {
                        anyhow::bail!("injected error at {i}");
                    }
                    panic!("injected panic at {i}");
                }
                for (j, x) in slice.iter_mut().enumerate() {
                    *x = (i * 100 + j) as f32;
                }
                Ok(())
            });
            // a failing closure must surface as Err - never a hang, and
            // never a panic escaping run()
            let err = result.unwrap_err().to_string();
            if as_error {
                assert!(err.contains("injected error"), "got: {err}");
            } else {
                assert!(err.contains("panicked"), "got: {err}");
            }
            // the same pool must keep serving correct batches afterwards
            let mut ok = vec![0f32; m * per];
            let work: Vec<(usize, &mut [f32])> =
                ok.chunks_mut(per).take(m).enumerate().collect();
            pool.run(work, |i, slice| {
                for (j, x) in slice.iter_mut().enumerate() {
                    *x = (i * 100 + j) as f32;
                }
                Ok(())
            })
            .unwrap();
            let expect: Vec<f32> = (0..m)
                .flat_map(|i| (0..per).map(move |j| (i * 100 + j) as f32))
                .collect();
            assert_eq!(ok, expect, "pool must stay healthy after an induced failure");
        }
    });
}

// --------------------------------------------------------- property 4

#[test]
fn prop_backend_dimension_agrees_within_declared_exactness() {
    // random batches x backends x {Inline, Scoped, Pool}: every backend
    // is held to its declared Exactness contract against the inline
    // Reference run — {Reference, Blocked} bit-identical, Simd within
    // its declared ulps budget — including n = 0, n = 1, and
    // fully-masked rows, so backend choice can never change a served
    // output beyond what the backend itself declares.
    check("backend_exactness", 96, |rng| {
        let b = rng.range(1, 4);
        let n = rng.range(0, 10);
        let d = rng.range(1, 10); // crosses the 4-wide column-tile boundary
        let patterns: Vec<Arc<CompiledPattern>> = (0..b)
            .map(|_| {
                let spec = if rng.chance(0.2) {
                    // explicit all-masked pattern: nothing is admitted
                    AttentionSpec::routing(vec![])
                } else {
                    small_spec(rng, n)
                };
                Arc::new(spec.compile(n))
            })
            .collect();
        let qkv: Vec<f32> = (0..3 * b * n * d).map(|_| rng.normal() as f32).collect();
        let (q, rest) = qkv.split_at(b * n * d);
        let (k, v) = rest.split_at(b * n * d);
        let workers = rng.range(1, 6);
        let batch = BatchedAttention::new(patterns.clone(), workers).unwrap();
        let reference = batch
            .attention_backend(q, k, v, d, Execution::Inline, &Reference)
            .unwrap();
        for exec in [Execution::Inline, Execution::default(), Execution::Scoped] {
            assert_eq!(
                batch.attention_backend(q, k, v, d, exec, &Blocked).unwrap(),
                reference,
                "Blocked/{exec:?} diverged at b={b} n={n} d={d} workers={workers}"
            );
            // the fast-math backend is held to its own declaration, and
            // must be execution-strategy-invariant bit-for-bit
            let simd = batch.attention_backend(q, k, v, d, exec, &Simd).unwrap();
            assert_outputs_match(&reference, &simd, Simd.exactness(), "Simd vs Reference")
                .unwrap_or_else(|e| {
                    panic!("Simd/{exec:?} at b={b} n={n} d={d} workers={workers}: {e}")
                });
            let simd_inline =
                batch.attention_backend(q, k, v, d, Execution::Inline, &Simd).unwrap();
            assert_outputs_match(&simd_inline, &simd, Exactness::Bitwise, "Simd across exec")
                .unwrap_or_else(|e| panic!("Simd not execution-invariant under {exec:?}: {e}"));
        }
        // registry-resolved backends agree too (the serve-bench path),
        // each under its own registered declaration
        for name in ["reference", "blocked", "simd"] {
            let backend = routing_transformer::attention::backend::lookup(name).unwrap();
            let out = batch
                .attention_backend(q, k, v, d, Execution::Inline, backend.as_ref())
                .unwrap();
            assert_outputs_match(&reference, &out, backend.exactness(), "registry backend")
                .unwrap_or_else(|e| panic!("registry backend '{name}' diverged: {e}"));
        }
        // the sharded single-sequence path gets the same guarantee
        if n > 0 {
            let sharded =
                ShardedPattern::balanced(Arc::clone(&patterns[0]), rng.range(1, 5)).unwrap();
            let hi = n * d;
            let base = sharded
                .attention_backend(&q[..hi], &k[..hi], &v[..hi], d, Execution::Inline, &Reference)
                .unwrap();
            for exec in [Execution::Inline, Execution::default(), Execution::Scoped] {
                assert_eq!(
                    sharded
                        .attention_backend(&q[..hi], &k[..hi], &v[..hi], d, exec, &Blocked)
                        .unwrap(),
                    base
                );
                let simd = sharded
                    .attention_backend(&q[..hi], &k[..hi], &v[..hi], d, exec, &Simd)
                    .unwrap();
                assert_outputs_match(&base, &simd, Simd.exactness(), "sharded Simd")
                    .unwrap_or_else(|e| panic!("sharded Simd/{exec:?} diverged: {e}"));
            }
            // and the one-shot Backend::attention convenience
            assert_eq!(Blocked.attention(&q[..hi], &k[..hi], &v[..hi], d, &patterns[0]).unwrap(), base);
            let simd_one = Simd.attention(&q[..hi], &k[..hi], &v[..hi], d, &patterns[0]).unwrap();
            assert_outputs_match(&base, &simd_one, Simd.exactness(), "one-shot Simd").unwrap();
        }
    });
}

// --------------------------------------------------------- property 5

#[test]
fn prop_incremental_regen_equals_from_scratch_with_exact_counters() {
    // random interleavings of k-means updates, content changes, width
    // changes, and spec regenerations: the incremental (dirty-cluster)
    // spec must always equal a from-scratch routing_spec, and the regen
    // counters must match a model that predicts touched clusters from an
    // independent k-means mirror (touched == clusters with a non-zero
    // pre-update assignment count).
    check("incremental_regen", 64, |rng| {
        let k = rng.range(1, 5);
        let n = rng.range(1, 12);
        let mut session = RoutingSession::new(1, 1, k, DIM, 0.3, rng.next_u64()).unwrap();
        let mut mirror = session.kmeans(0, 0).clone();
        let mut members = MemberCache::new();
        let mut xs = random_xs(rng, n);
        let mut w = rng.range(1, n + 1);
        // model of the member cache's keying state
        let mut model_versions = vec![0u64; k];
        let mut cached: Option<(Vec<u64>, Vec<f32>, usize)> = None; // (versions, xs, w_eff)
        let mut dirty_model: BTreeSet<usize> = BTreeSet::new();
        for _op in 0..rng.range(8, 20) {
            match rng.below(6) {
                0 | 1 => {
                    // k-means step over a random (possibly empty) batch
                    let m = rng.range(0, 8);
                    let batch = random_xs(rng, m);
                    let delta = mirror.update(&batch, m);
                    let upd = session.update(0, 0, &batch, m);
                    assert_eq!(upd.delta.counts, delta.counts, "mirror in lockstep");
                    if m > 0 {
                        for (c, &count) in delta.counts.iter().enumerate() {
                            if count > 0 {
                                model_versions[c] += 1;
                                dirty_model.insert(c);
                            }
                        }
                    }
                    assert_eq!(
                        session.dirty_clusters(0, 0),
                        dirty_model.iter().copied().collect::<Vec<_>>(),
                        "dirty-cluster worklist"
                    );
                    assert_eq!(session.cluster_versions(0, 0), model_versions.as_slice());
                }
                2 => {
                    // content change: every cached list goes stale at once
                    xs = random_xs(rng, n);
                }
                3 => {
                    w = rng.range(1, n + 1);
                }
                4 => {
                    // drain the worklist like an external re-router would
                    let drained = session.take_dirty_clusters(0, 0);
                    assert_eq!(drained, dirty_model.iter().copied().collect::<Vec<_>>());
                    dirty_model.clear();
                    assert_eq!(session.dirty_cluster_len(0, 0), 0);
                }
                _ => {
                    let before = members.stats();
                    let spec = session.routing_spec_cached(0, 0, &mut members, &xs, n, w);
                    assert_eq!(
                        spec,
                        session.routing_spec(0, 0, &xs, n, w),
                        "incremental spec must equal from-scratch at k={k} n={n} w={w}"
                    );
                    let after = members.stats();
                    let w_eff = w.min(n);
                    let predict_full = match &cached {
                        None => true,
                        Some((_, cxs, cw)) => cxs != &xs || *cw != w_eff,
                    };
                    if predict_full {
                        assert_eq!(after.full_rebuilds, before.full_rebuilds + 1);
                        assert_eq!(after.regenerated, before.regenerated + k as u64);
                        assert_eq!(after.reused, before.reused);
                    } else {
                        let stale = cached
                            .as_ref()
                            .map(|(cv, _, _)| {
                                cv.iter().zip(&model_versions).filter(|(a, b)| a != b).count()
                            })
                            .unwrap();
                        assert_eq!(after.full_rebuilds, before.full_rebuilds);
                        assert_eq!(
                            after.regenerated,
                            before.regenerated + stale as u64,
                            "exactly the delta-touched clusters re-rank"
                        );
                        assert_eq!(after.reused, before.reused + (k - stale) as u64);
                    }
                    cached = Some((model_versions.clone(), xs.clone(), w_eff));
                }
            }
        }
    });
}

// -------------------------------------------------------- property 5b

#[test]
fn prop_mixed_family_slots_share_caches_with_exact_counters() {
    // Random op sequences mixing an expert-choice slot with a classic
    // routing slot over ONE RoutingSession, both served through the same
    // EpochCache plus per-slot MemberCaches: every epoch-cache
    // hit/miss/eviction/unchanged counter and every member regen counter
    // must match an independent model (k-means mirror; the expert side
    // uses the stricter version-AND-bucket reuse rule), and a capacity
    // change must force a full member rebuild — never stale reuse.
    check("mixed_family_slots", 64, |rng| {
        let k = rng.range(1, 5);
        let n = rng.range(1, 12);
        let mut session = RoutingSession::new(1, 1, k, DIM, 0.3, rng.next_u64()).unwrap();
        let mut mirror = session.kmeans(0, 0).clone();
        let mut cache = EpochCache::new();
        let mut mc_route = MemberCache::new();
        let mut mc_expert = MemberCache::new();
        let route_slot = RouteSlot { layer: 0, head: 0, seq: 0 };
        let expert_slot = RouteSlot { layer: 0, head: 0, seq: 1 };
        let mut xs = random_xs(rng, n);
        let mut w = rng.range(1, n + 1);
        let mut capacity = rng.range(0, n + 2);
        let mut model_versions = vec![0u64; k];
        // member-cache keying models: routing keys on (versions, xs,
        // w_eff); expert keys on (versions, buckets, xs, cap_eff)
        let mut cached_r: Option<(Vec<u64>, Vec<f32>, usize)> = None;
        let mut cached_e: Option<(Vec<u64>, Vec<Vec<usize>>, Vec<f32>, usize)> = None;
        // epoch-cache entry models: (cluster epoch, assignment epoch)
        let mut entry_r: Option<(u64, u64)> = None;
        let mut entry_e: Option<(u64, u64)> = None;
        let mut want = cache.epoch_stats();
        let mut evictions = 0u64;

        // apply the routing member model to a regen that just ran
        let route_regen = |cached_r: &mut Option<(Vec<u64>, Vec<f32>, usize)>,
                           model_versions: &Vec<u64>,
                           xs: &Vec<f32>,
                           w_eff: usize,
                           before: routing_transformer::attention::RegenStats,
                           after: routing_transformer::attention::RegenStats| {
            let full = match cached_r {
                None => true,
                Some((_, cxs, cw)) => cxs != xs || *cw != w_eff,
            };
            if full {
                assert_eq!(after.full_rebuilds, before.full_rebuilds + 1);
                assert_eq!(after.regenerated, before.regenerated + model_versions.len() as u64);
                assert_eq!(after.reused, before.reused);
            } else {
                let (cv, _, _) = cached_r.as_ref().unwrap();
                let stale = cv.iter().zip(model_versions).filter(|(a, b)| a != b).count();
                assert_eq!(after.full_rebuilds, before.full_rebuilds);
                assert_eq!(after.regenerated, before.regenerated + stale as u64);
                assert_eq!(
                    after.reused,
                    before.reused + (model_versions.len() - stale) as u64
                );
            }
            *cached_r = Some((model_versions.clone(), xs.clone(), w_eff));
        };

        for _op in 0..rng.range(10, 24) {
            match rng.below(8) {
                0 | 1 => {
                    // k-means step over a random (possibly empty) batch
                    let m = rng.range(0, 8);
                    let batch = random_xs(rng, m);
                    let delta = mirror.update(&batch, m);
                    session.update(0, 0, &batch, m);
                    if m > 0 {
                        for (c, &count) in delta.counts.iter().enumerate() {
                            if count > 0 {
                                model_versions[c] += 1;
                            }
                        }
                    }
                    assert_eq!(session.cluster_versions(0, 0), model_versions.as_slice());
                }
                2 => xs = random_xs(rng, n),
                3 => w = rng.range(1, n + 1),
                4 => capacity = rng.range(0, n + 2),
                5 => {
                    // routing slot through the shared EpochCache
                    let epoch = session.epoch(0, 0);
                    let ae = session.assignment_epoch(0, 0);
                    let before = mc_route.stats();
                    let hit = entry_r.is_some_and(|(_, cae)| cae == ae);
                    cache.get_routed_at(route_slot, epoch, ae, n, || {
                        session.routing_spec_cached(0, 0, &mut mc_route, &xs, n, w)
                    });
                    if hit {
                        want.epoch_hits += 1;
                        if entry_r.unwrap().0 != epoch {
                            want.unchanged_epochs += 1;
                        }
                        assert_eq!(mc_route.stats(), before, "a hit never regenerates");
                    } else {
                        want.epoch_misses += 1;
                        if entry_r.is_some() {
                            evictions += 1; // stale entry replaced
                        }
                        route_regen(
                            &mut cached_r,
                            &model_versions,
                            &xs,
                            w.min(n),
                            before,
                            mc_route.stats(),
                        );
                    }
                    entry_r = Some((epoch, ae));
                }
                6 => {
                    // expert slot through the shared EpochCache
                    let epoch = session.epoch(0, 0);
                    let ae = session.assignment_epoch(0, 0);
                    let before = mc_expert.stats();
                    let hit = entry_e.is_some_and(|(_, cae)| cae == ae);
                    let mut made: Option<AttentionSpec> = None;
                    cache.get_routed_at(expert_slot, epoch, ae, n, || {
                        let spec =
                            session.expert_choice_spec_cached(0, 0, &mut mc_expert, &xs, n, capacity);
                        made = Some(spec.clone());
                        spec
                    });
                    if hit {
                        want.epoch_hits += 1;
                        if entry_e.unwrap().0 != epoch {
                            want.unchanged_epochs += 1;
                        }
                        assert_eq!(mc_expert.stats(), before, "a hit never regenerates");
                    } else {
                        want.epoch_misses += 1;
                        if entry_e.is_some() {
                            evictions += 1;
                        }
                        let spec = made.expect("a miss regenerates");
                        assert_eq!(
                            spec,
                            session.expert_choice_spec(0, 0, &xs, n, capacity),
                            "incremental expert spec must equal from-scratch"
                        );
                        let AttentionSpec::ExpertChoice { clusters, capacity: cap } = &spec
                        else {
                            panic!("expert family must produce an ExpertChoice spec")
                        };
                        assert_eq!(*cap, capacity);
                        for m in clusters {
                            assert!(m.len() <= capacity, "capacity bound on every regen");
                        }
                        // stricter reuse model: full rebuild on any shape
                        // change (content or capacity), else per-cluster
                        // version AND bucket equality
                        let cap_eff = capacity.min(n);
                        let after = mc_expert.stats();
                        let full = match &cached_e {
                            None => true,
                            Some((_, _, cxs, ccap)) => cxs != &xs || *ccap != cap_eff,
                        };
                        if full {
                            assert_eq!(
                                after.full_rebuilds,
                                before.full_rebuilds + 1,
                                "shape change (content/capacity) is a full rebuild"
                            );
                            assert_eq!(after.regenerated, before.regenerated + k as u64);
                            assert_eq!(after.reused, before.reused);
                            let buckets = mirror.assigned_buckets(&xs, n);
                            cached_e =
                                Some((model_versions.clone(), buckets, xs.clone(), cap_eff));
                        } else if cached_e.as_ref().unwrap().0 == model_versions {
                            // no centroid moved: the assignment pass is
                            // skipped and every cluster is reused
                            assert_eq!(after.full_rebuilds, before.full_rebuilds);
                            assert_eq!(after.regenerated, before.regenerated);
                            assert_eq!(after.reused, before.reused + k as u64);
                        } else {
                            let buckets = mirror.assigned_buckets(&xs, n);
                            let (cv, cb, _, _) = cached_e.as_ref().unwrap();
                            let stale = (0..k)
                                .filter(|&c| {
                                    cv[c] != model_versions[c] || cb[c] != buckets[c]
                                })
                                .count();
                            assert_eq!(after.full_rebuilds, before.full_rebuilds);
                            assert_eq!(after.regenerated, before.regenerated + stale as u64);
                            assert_eq!(after.reused, before.reused + (k - stale) as u64);
                            cached_e =
                                Some((model_versions.clone(), buckets, xs.clone(), cap_eff));
                        }
                    }
                    entry_e = Some((epoch, ae));
                }
                _ => {
                    // evict one slot: freed bytes iff the model says the
                    // entry was resident
                    let (slot, entry) = if rng.chance(0.5) {
                        (route_slot, &mut entry_r)
                    } else {
                        (expert_slot, &mut entry_e)
                    };
                    let freed = cache.evict_slot(slot);
                    assert_eq!(freed.is_some(), entry.is_some(), "eviction parity");
                    if entry.take().is_some() {
                        evictions += 1;
                    }
                }
            }
            let got = cache.epoch_stats();
            assert_eq!(got.epoch_hits, want.epoch_hits, "epoch hits");
            assert_eq!(got.epoch_misses, want.epoch_misses, "epoch misses");
            assert_eq!(got.unchanged_epochs, want.unchanged_epochs, "unchanged epochs");
            let cs = cache.stats();
            assert_eq!(cs.hits, want.epoch_hits, "compile-cache hits mirror");
            assert_eq!(cs.misses, want.epoch_misses, "compile-cache misses mirror");
            assert_eq!(cs.evictions, evictions, "exact eviction count");
        }
    });
}

// --------------------------------------------------------- property 6

#[test]
fn prop_single_cluster_epoch_bumps_are_unchanged_hits() {
    // k = 1 pins every assignment to cluster 0 forever, so every re-fit
    // bumps the cluster epoch without moving a token: the incremental
    // flow must serve the original compile for the whole session, and
    // with w = n the reuse is semantically exact (every token is always
    // a member), not just assignment-stable.
    check("single_cluster_unchanged", 64, |rng| {
        let n = rng.range(2, 12);
        let mut session = RoutingSession::new(1, 1, 1, DIM, 0.5, rng.next_u64()).unwrap();
        let mut cache = EpochCache::new();
        let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
        let xs = random_xs(rng, n);
        let p0 = session.routed_pattern(&mut cache, slot, &xs, n, n);
        let rounds = rng.range(1, 5);
        for round in 1..=rounds {
            let xs2 = random_xs(rng, n);
            let upd = session.update(0, 0, &xs2, n);
            assert!(!upd.delta.changed(), "k = 1 can never move a token");
            assert_eq!(upd.epoch, round as u64);
            assert_eq!(upd.assignment_epoch, 0);
            assert_eq!(session.dirty_len(0, 0), 0);
            let p = session.routed_pattern(&mut cache, slot, &xs2, n, n);
            assert!(
                Arc::ptr_eq(&p0, &p),
                "unchanged assignments must keep serving the live compile"
            );
            assert_eq!(*p, session.routing_spec(0, 0, &xs2, n, n).compile(n));
        }
        let es = cache.epoch_stats();
        assert_eq!(es.unchanged_epochs, rounds as u64);
        assert_eq!(es.epoch_hits, rounds as u64);
        assert_eq!(es.epoch_misses, 1, "only the initial compile misses");
        assert_eq!(cache.stats().evictions, 0, "no eviction across the whole session");
        assert_eq!(cache.len(), 1);
    });
}

// --------------------------------------------------------- property 7

/// Naive mirror of the serve-layer `Scheduler` plus the `EpochCache`
/// entries its retirement GC owns: one wait queue, one slot map, one
/// outcome ledger, and a live-routed-entry set, all evolved by the
/// documented semantics only.
struct SchedMirror {
    now: u64,
    waiting: VecDeque<ServeRequest>,
    /// slot -> (id, content, remaining, deadline)
    active: BTreeMap<usize, (u64, usize, u64, u64)>,
    free: BTreeSet<usize>,
    outcomes: Vec<RequestOutcome>,
    /// (layer, head, slot) routed entries compiled into the cache.
    live: HashSet<(usize, usize, usize)>,
    stats: ServeStats,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl SchedMirror {
    fn new(capacity: usize) -> SchedMirror {
        SchedMirror {
            now: 0,
            waiting: VecDeque::new(),
            active: BTreeMap::new(),
            free: (0..capacity).collect(),
            outcomes: Vec::new(),
            live: HashSet::new(),
            stats: ServeStats::default(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }
}

/// One full begin/touch/finish step cycle, mirrored and asserted:
/// shed-sweep before FIFO admission into lowest free slots, batch
/// snapshot slot-ascending, completion at `now + 1`, and retirement GC
/// evicting exactly the slot's live routed entries.  `touches` are
/// mid-step `get_routed_at` probes: `(layer, head, pick)` selects the
/// `pick % active`-th live slot.
fn sched_model_step(
    sched: &mut Scheduler,
    cache: &mut EpochCache,
    m: &mut SchedMirror,
    touches: &[(usize, usize, usize)],
) {
    const N: usize = 6;
    let now = m.now;
    let plan = sched.begin_step();
    m.stats.steps += 1;

    // model: shed the whole queue's infeasible tail first
    let mut shed = Vec::new();
    let mut kept = VecDeque::new();
    for req in m.waiting.drain(..) {
        if now + req.work > req.deadline {
            m.stats.shed += 1;
            m.outcomes.push(RequestOutcome { id: req.id, kind: OutcomeKind::Shed, at: now });
            shed.push(req.id);
        } else {
            kept.push_back(req);
        }
    }
    m.waiting = kept;

    // model: FIFO admission into the lowest free slots
    let mut admitted = Vec::new();
    while !m.waiting.is_empty() {
        let Some(&slot) = m.free.iter().next() else { break };
        let req = m.waiting.pop_front().unwrap();
        m.free.remove(&slot);
        m.active.insert(slot, (req.id, req.content, req.work, req.deadline));
        m.stats.admitted += 1;
        admitted.push(BatchEntry {
            id: req.id,
            slot,
            content: req.content,
            remaining: req.work,
            deadline: req.deadline,
        });
    }
    let batch: Vec<BatchEntry> = m
        .active
        .iter()
        .map(|(&slot, &(id, content, remaining, deadline))| BatchEntry {
            id,
            slot,
            content,
            remaining,
            deadline,
        })
        .collect();
    m.stats.peak_active = m.stats.peak_active.max(batch.len());
    if batch.is_empty() {
        m.stats.idle_steps += 1;
    }
    assert_eq!(plan.step, now, "step stamp");
    assert_eq!(plan.shed, shed, "shed ids in queue order");
    assert_eq!(plan.admitted, admitted, "FIFO admission into lowest free slots");
    assert_eq!(plan.batch, batch, "batch snapshot, slot-ascending");

    // mid-step routed-cache touches: the first touch of a (layer, head,
    // slot) compiles (miss), re-touches hit the live entry
    for &(layer, head, pick) in touches {
        if m.active.is_empty() {
            break;
        }
        let slots: Vec<usize> = m.active.keys().copied().collect();
        let slot = slots[pick % slots.len()];
        let key = (layer, head, slot);
        let hit = m.live.contains(&key);
        if hit {
            m.hits += 1;
        } else {
            m.misses += 1;
            m.live.insert(key);
        }
        let compiled = Cell::new(false);
        cache.get_routed_at(RouteSlot { layer, head, seq: slot }, 0, 0, N, || {
            compiled.set(true);
            AttentionSpec::local(2).unwrap()
        });
        assert_eq!(compiled.get(), !hit, "compile exactly on first touch of a slot");
    }

    // model: charge one step, retire at zero, GC the slot's live entries
    let fin = sched.finish_step(cache);
    let mut retired = Vec::new();
    let mut gc = 0u64;
    let slots: Vec<usize> = m.active.keys().copied().collect();
    for slot in slots {
        let e = m.active.get_mut(&slot).unwrap();
        e.2 -= 1;
        if e.2 == 0 {
            let (id, ..) = m.active.remove(&slot).unwrap();
            m.free.insert(slot);
            m.stats.completed += 1;
            m.outcomes.push(RequestOutcome { id, kind: OutcomeKind::Completed, at: now + 1 });
            for layer in 0..LAYERS {
                for head in 0..HEADS {
                    if m.live.remove(&(layer, head, slot)) {
                        gc += 1;
                        m.evictions += 1;
                    }
                }
            }
            retired.push(Retired { id, slot, completed_at: now + 1 });
        }
    }
    m.stats.gc_evictions += gc;
    m.now = now + 1;
    assert_eq!(fin.step, now);
    assert_eq!(fin.retired, retired, "retirements in slot order at now + 1");
    assert_eq!(fin.gc_evictions, gc, "GC evicts exactly the live routed entries");

    // full state agreement after every step
    assert_eq!(sched.stats(), m.stats, "scheduler counters");
    assert_eq!(sched.now(), m.now);
    assert_eq!(sched.active_len(), m.active.len());
    assert_eq!(sched.waiting_len(), m.waiting.len());
    let cs = cache.stats();
    assert_eq!(cs.hits, m.hits, "cache hits");
    assert_eq!(cs.misses, m.misses, "cache misses");
    assert_eq!(cs.evictions, m.evictions, "cache evictions == retirement GC");
    assert_eq!(cache.len(), m.live.len(), "live compiles == model live set");
}

#[test]
fn prop_scheduler_matches_reference_model() {
    // Random submit / step / cache-touch / fast-forward sequences against
    // the naive mirror: reject iff `now + work > deadline` (or work == 0)
    // at submit, shed-sweep before FIFO admission, completion at
    // `now + 1`, retirement GC evicting exactly the live routed entries.
    // After a bounded drain every submitted request must appear in the
    // ledger exactly once and every counter must match the model.
    check("scheduler_model", 64, |rng| {
        let capacity = rng.range(1, 4);
        let mut sched = Scheduler::new(capacity, LAYERS, HEADS).unwrap();
        let mut cache = EpochCache::new();
        let mut m = SchedMirror::new(capacity);
        let mut next_id = 0u64;
        for _op in 0..rng.range(12, 28) {
            match rng.below(5) {
                // Submit: random work (0 exercises the degenerate reject)
                // and a deadline tight enough to trigger both verdicts
                0..=1 => {
                    let id = next_id;
                    next_id += 1;
                    let work = rng.below(4) as u64;
                    let deadline = m.now + rng.below(10) as u64;
                    let req = ServeRequest {
                        id,
                        content: rng.below(8),
                        arrival: m.now,
                        work,
                        deadline,
                    };
                    let expect_reject = work == 0 || m.now + work > deadline;
                    m.stats.submitted += 1;
                    if expect_reject {
                        m.stats.rejected += 1;
                        m.outcomes.push(RequestOutcome {
                            id,
                            kind: OutcomeKind::Rejected,
                            at: m.now,
                        });
                    } else {
                        m.stats.queued += 1;
                        m.waiting.push_back(req);
                    }
                    let got = sched.submit(req);
                    assert_eq!(
                        got == Submission::Rejected,
                        expect_reject,
                        "admission-control verdict at now={} work={work} deadline={deadline}",
                        m.now
                    );
                    assert_eq!(sched.stats(), m.stats);
                }
                // Step (with 0-2 mid-step cache touches)
                2..=3 => {
                    let touches: Vec<(usize, usize, usize)> = (0..rng.below(3))
                        .map(|_| (rng.below(LAYERS), rng.below(HEADS), rng.below(16)))
                        .collect();
                    sched_model_step(&mut sched, &mut cache, &mut m, &touches);
                }
                // FastForward (idle only — mirrors run_serve's guard)
                _ => {
                    if sched.is_idle() {
                        let to = m.now + rng.below(6) as u64;
                        sched.fast_forward(to);
                        if to > m.now {
                            m.stats.fast_forwarded += to - m.now;
                            m.now = to;
                        }
                        assert_eq!(sched.now(), m.now);
                        assert_eq!(sched.stats(), m.stats);
                    }
                }
            }
        }
        // drain: finite work + finite deadlines means this terminates
        let mut guard = 0;
        while !sched.is_idle() {
            sched_model_step(&mut sched, &mut cache, &mut m, &[]);
            guard += 1;
            assert!(guard < 512, "drain must terminate");
        }
        assert_eq!(m.stats.submitted, next_id);
        assert_eq!(
            m.stats.resolved(),
            next_id,
            "every submitted request reaches exactly one terminal state"
        );
        assert_eq!(sched.outcomes(), m.outcomes.as_slice(), "exact ledger, exact order");
        let mut ids: Vec<u64> = sched.outcomes().iter().map(|o| o.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..next_id).collect::<Vec<_>>(), "each id exactly once");
        assert_eq!(cache.len(), m.live.len());
        assert!(m.live.is_empty(), "a full drain GCs every routed entry");
    });
}

// --------------------------------------------------------- property 8

#[test]
fn prop_budgeted_epoch_cache_matches_lru_spill_model() {
    // Random lookup / mark_step / evict_slot sequences against a naive
    // mirror of the budgeted cache's documented policy: a routed miss
    // charges the shared meter and then spills least-recently-used slots
    // in deterministic tick order — never the slot just touched, never an
    // entry touched since the last `mark_step()`, never the pinned static
    // — until the budget is satisfied or only protected entries remain.
    check("budgeted_epoch_cache_model", 64, |rng| {
        let max = rng.range(64, 1024);
        let budget = MemoryBudget::bytes(max);
        let mut cache = EpochCache::with_budget(budget.clone());
        let static_spec = AttentionSpec::local(2).unwrap();
        let static_n = rng.range(1, 8);
        let pinned = cache.get_static(&static_spec, static_n);
        let static_bytes = static_spec.compile(static_n).heap_bytes();

        type Key = (usize, usize, usize);
        // key -> (assignment_epoch, n, bytes, last_used tick)
        let mut slots: HashMap<Key, (u64, usize, usize, u64)> = HashMap::new();
        let mut tick = 0u64;
        let mut step_mark = u64::MAX;
        let mut evictions = 0u64;
        let mut bytes_evicted = 0u64;
        let resident = |slots: &HashMap<Key, (u64, usize, usize, u64)>| -> usize {
            slots.values().map(|e| e.2).sum()
        };

        for _op in 0..rng.range(10, 24) {
            match rng.below(8) {
                // routed lookup: a hit refreshes recency only; a miss
                // replaces any stale entry, charges, then LRU-spills
                0..=4 => {
                    let key: Key = (rng.below(LAYERS), rng.below(HEADS), rng.below(3));
                    let slot = RouteSlot { layer: key.0, head: key.1, seq: key.2 };
                    let ae = rng.below(3) as u64;
                    let n = rng.range(1, 10);
                    let spec = {
                        let mut clusters: Vec<Vec<usize>> = vec![(0..n).collect()];
                        clusters.push((0..n).filter(|_| rng.chance(0.4)).collect());
                        AttentionSpec::routing(clusters)
                    };
                    tick += 1;
                    let hit = slots.get(&key).is_some_and(|e| e.0 == ae && e.1 == n);
                    if hit {
                        slots.get_mut(&key).unwrap().3 = tick;
                        cache.get_routed_at(slot, ae, ae, n, || {
                            panic!("hit must not regenerate")
                        });
                    } else {
                        if let Some(stale) = slots.remove(&key) {
                            evictions += 1;
                            bytes_evicted += stale.2 as u64;
                        }
                        let bytes = spec.compile(n).heap_bytes();
                        slots.insert(key, (ae, n, bytes, tick));
                        cache.get_routed_at(slot, ae, ae, n, || spec.clone());
                        // mirror the deterministic LRU spill
                        while static_bytes + resident(&slots) > max {
                            let victim = slots
                                .iter()
                                .filter(|&(k2, e)| *k2 != key && e.3 < step_mark)
                                .min_by_key(|&(_, e)| e.3)
                                .map(|(k2, _)| *k2);
                            let Some(v) = victim else { break };
                            let e = slots.remove(&v).unwrap();
                            evictions += 1;
                            bytes_evicted += e.2 as u64;
                        }
                        // the spill postcondition: over budget only while
                        // every survivor is the kept slot or step-touched
                        if static_bytes + resident(&slots) > max {
                            assert!(
                                slots
                                    .iter()
                                    .all(|(k2, e)| *k2 == key || e.3 >= step_mark),
                                "soft cap: only protected entries may hold \
                                 residency over budget"
                            );
                        }
                    }
                }
                // step boundary: entries touched after this are protected
                5 => {
                    cache.mark_step();
                    step_mark = tick + 1;
                }
                // retirement GC returns the bytes it freed
                6 => {
                    let key: Key = (rng.below(LAYERS), rng.below(HEADS), rng.below(3));
                    let slot = RouteSlot { layer: key.0, head: key.1, seq: key.2 };
                    let expect = slots.remove(&key).map(|e| {
                        evictions += 1;
                        bytes_evicted += e.2 as u64;
                        e.2
                    });
                    assert_eq!(cache.evict_slot(slot), expect, "evict_slot returns bytes freed");
                }
                _ => {} // idle op: state must be stable without lookups
            }
            let slot_bytes = resident(&slots);
            assert_eq!(
                budget.resident(),
                static_bytes + slot_bytes,
                "shared meter tracks pinned static + live routed bytes exactly"
            );
            let es = cache.epoch_stats();
            assert_eq!(es.bytes_resident, slot_bytes as u64, "routed-side resident gauge");
            assert_eq!(es.bytes_evicted, bytes_evicted, "routed-side evicted bytes");
            assert_eq!(cache.stats().evictions, evictions, "eviction count");
            assert_eq!(cache.len(), 1 + slots.len(), "pinned static + one per live slot");
        }
        // the pinned static survived arbitrary budgeted churn
        assert!(
            Arc::ptr_eq(&pinned, &cache.get_static(&static_spec, static_n)),
            "pinned static must never spill"
        );
        drop(cache);
        assert_eq!(budget.resident(), 0, "dropping the cache returns every charged byte");
    });
}

// --------------------------------------------------------- property 9

#[test]
fn prop_scheduler_crash_during_step_resolves_exactly_once() {
    // The serve-layer crash story: decode steps run their attention
    // through a `Coordinator<SimTransport>` whose workers die (and
    // rejoin) mid-step.  The scheduler must still resolve every
    // submitted request exactly once, every attention output must stay
    // bit-identical to the inline reference, and the coordinator's grant
    // ledger must conserve through every crash — no row computed twice,
    // none lost.
    check("scheduler_crash_during_step", 48, |rng| {
        const REQUESTS: u64 = 8;
        let cfg = CoordinatorConfig {
            n: rng.range(8, 17),
            d: 3,
            layers: LAYERS,
            heads: HEADS,
            window: 3,
            clusters: 2,
            top_w: 4,
            capacity: rng.range(1, 4),
            seed: rng.next_u64(),
            backend: "reference".to_string(),
            max_regrants: 4,
            spec_family: SpecFamily::Routing,
        };
        let static_pattern = AttentionSpec::local(cfg.window).unwrap().compile(cfg.n);
        let mut coord = Coordinator::new(cfg.clone(), SimTransport::new()).unwrap();
        let workers = [coord.spawn_worker().unwrap(), coord.spawn_worker().unwrap()];
        let mut sched = Scheduler::new(cfg.capacity, LAYERS, HEADS).unwrap();
        let mut next_id = 0u64;
        let mut expected_rows = 0u64;
        let mut steps = 0u64;
        loop {
            if next_id < REQUESTS && (sched.is_idle() || rng.chance(0.6)) {
                let req = ServeRequest {
                    id: next_id,
                    content: rng.below(4),
                    arrival: sched.now(),
                    work: rng.range(1, 4) as u64,
                    deadline: sched.now() + rng.range(2, 12) as u64,
                };
                next_id += 1;
                let _ = sched.submit(req);
            }
            if next_id >= REQUESTS && sched.is_idle() {
                break;
            }
            let plan = sched.begin_step();
            coord.mark_step();
            if rng.chance(0.3) {
                // schedule a mid-step crash: the next grant (or install)
                // sent to this worker kills it before processing
                let alive: Vec<usize> = workers
                    .iter()
                    .copied()
                    .filter(|&w| coord.worker_state(w) != Some(WorkerState::Crashed))
                    .collect();
                if !alive.is_empty() {
                    let w = alive[rng.below(alive.len())];
                    let nth = rng.range(1, 3) as u64;
                    coord.transport_mut().crash_on_nth_message(w, nth);
                }
            }
            for _e in &plan.batch {
                let q: Vec<f32> = (0..cfg.n * cfg.d).map(|_| rng.normal() as f32).collect();
                let k: Vec<f32> = (0..cfg.n * cfg.d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..cfg.n * cfg.d).map(|_| rng.normal() as f32).collect();
                let (got, _) = coord.static_attention(&q, &k, &v).unwrap();
                let want = Reference.attention(&q, &k, &v, cfg.d, &static_pattern).unwrap();
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "value {i} differs under mid-step crashes ({g} vs {w})"
                    );
                }
                expected_rows += cfg.n as u64;
            }
            let _fin = sched.finish_step(coord.cache_mut());
            for &w in &workers {
                if coord.worker_state(w) == Some(WorkerState::Crashed) && rng.chance(0.7) {
                    coord.rejoin_worker(w).unwrap();
                }
            }
            let st = coord.stats();
            assert!(st.conserved(), "ledger conservation after step: {st:?}");
            assert_eq!(
                st.worker_rows + st.inline_rows,
                expected_rows,
                "every batch row computed exactly once: {st:?}"
            );
            steps += 1;
            assert!(steps < 512, "drain must terminate");
        }
        assert_eq!(sched.stats().submitted, next_id);
        assert_eq!(
            sched.stats().resolved(),
            next_id,
            "every request reaches exactly one terminal state despite crashes"
        );
        let mut ids: Vec<u64> = sched.outcomes().iter().map(|o| o.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..next_id).collect::<Vec<_>>(), "each id exactly once in the ledger");
        coord.shutdown();
    });
}

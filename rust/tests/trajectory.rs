//! Schema validation for the persisted serve-perf trajectory.
//!
//! `BENCH_serve.json` (repository root) is a JSONL file: every CI run of
//! the `rust-host` job appends one `rtx serve --json` line, so the file
//! accumulates lines written by *different commits* — and therefore by
//! different schema versions.  This suite `include_str!`s the file so
//! the trajectory is validated at test time on every commit: each line
//! must parse as JSON and satisfy the field contract of the schema
//! version it declares (1 through the current version 6, per the schema
//! history in ARCHITECTURE.md):
//!
//! - all versions: config echo, request ledger, time accounting, step
//!   latency percentiles, throughput, and the `cache`/`epoch`/`regen`
//!   sub-objects;
//! - schema >= 3: byte accounting (`cache.bytes_resident`/`_evicted`,
//!   `peak_pattern_bytes` family, `band_compiles`, `gc_bytes_reclaimed`);
//! - schema >= 4: exactness contract (`backend` + `exactness` strings);
//! - schema >= 5: multi-process fields (`worker_procs`, `output_digest`
//!   as a 16-hex-digit string, and — iff `worker_procs > 0` — a `coord`
//!   object whose ledger conserves: grants == accepted + superseded +
//!   voided, regrants <= superseded + voided);
//! - schema >= 6: content-based spec families (`spec_family` naming one
//!   of the `--spec` values, plus the load-balance observables
//!   `max_cluster_nnz` and `max_shard_nnz`/`min_shard_nnz` with
//!   min <= max).
//!
//! The file is seeded with one zeroed schema-6 line so the parser always
//! has at least one line to chew on (a 0-byte trajectory would make
//! every consumer's "parse each line" loop vacuously green).

use routing_transformer::util::json::Json;

/// Mirrors `JSON_SCHEMA_VERSION` in `src/main.rs` (a binary-only const,
/// so the test pins its own copy; `docs.rs` anchors the prose history).
const MAX_SCHEMA: i64 = 6;

const TRAJECTORY: &str = include_str!("../../BENCH_serve.json");

/// Fetch `key` from an object, panicking with line context.
fn field<'a>(line_no: usize, obj: &'a Json, key: &str) -> &'a Json {
    obj.get(key)
        .unwrap_or_else(|| panic!("line {line_no}: missing field {key:?}"))
}

fn num(line_no: usize, obj: &Json, key: &str) -> f64 {
    field(line_no, obj, key)
        .as_f64()
        .unwrap_or_else(|| panic!("line {line_no}: field {key:?} is not a number"))
}

/// A counter: a number that is finite and >= 0.
fn counter(line_no: usize, obj: &Json, key: &str) -> f64 {
    let v = num(line_no, obj, key);
    assert!(
        v.is_finite() && v >= 0.0,
        "line {line_no}: counter {key:?} = {v} is not a finite non-negative number"
    );
    v
}

fn str_field<'a>(line_no: usize, obj: &'a Json, key: &str) -> &'a str {
    field(line_no, obj, key)
        .as_str()
        .unwrap_or_else(|| panic!("line {line_no}: field {key:?} is not a string"))
}

/// A `[lo, hi]` pair with lo <= hi.
fn pair(line_no: usize, obj: &Json, key: &str) {
    let arr = field(line_no, obj, key)
        .as_arr()
        .unwrap_or_else(|| panic!("line {line_no}: field {key:?} is not an array"));
    assert_eq!(arr.len(), 2, "line {line_no}: {key:?} must be [lo, hi]");
    let lo = arr[0].as_f64().expect("lo is a number");
    let hi = arr[1].as_f64().expect("hi is a number");
    assert!(lo <= hi, "line {line_no}: {key:?} = [{lo}, {hi}] has lo > hi");
}

/// Validate one trajectory line against the schema version it declares.
fn check_line(line_no: usize, line: &Json) {
    assert_eq!(
        str_field(line_no, line, "bench"),
        "serve",
        "line {line_no}: trajectory lines must be `rtx serve` lines"
    );
    let schema = field(line_no, line, "schema")
        .as_i64()
        .unwrap_or_else(|| panic!("line {line_no}: schema is not an integer"));
    assert!(
        (1..=MAX_SCHEMA).contains(&schema),
        "line {line_no}: schema {schema} outside 1..={MAX_SCHEMA} — bump MAX_SCHEMA \
         (and this suite's per-version checks) together with JSON_SCHEMA_VERSION"
    );

    // Config echo (all versions).
    for key in [
        "n",
        "d",
        "heads",
        "layers",
        "window",
        "clusters",
        "capacity",
        "workers",
        "route_every",
        "requests",
        "contents",
        "seed",
    ] {
        counter(line_no, line, key);
    }
    counter(line_no, line, "rate");
    counter(line_no, line, "zipf_s");
    pair(line_no, line, "work");
    pair(line_no, line, "slack");

    // Request ledger: every submitted request reaches exactly one
    // terminal state (the `ServeStats` contract), completions were
    // admitted first, and rejected/admitted are disjoint populations.
    let submitted = counter(line_no, line, "submitted");
    let admitted = counter(line_no, line, "admitted");
    let completed = counter(line_no, line, "completed");
    let rejected = counter(line_no, line, "rejected");
    let shed = counter(line_no, line, "shed");
    counter(line_no, line, "peak_active");
    let rate = num(line_no, line, "completion_rate");
    assert!(
        (0.0..=1.0).contains(&rate),
        "line {line_no}: completion_rate {rate} outside [0, 1]"
    );
    assert_eq!(
        completed + rejected + shed,
        submitted,
        "line {line_no}: terminal states do not partition submitted"
    );
    assert!(
        completed <= admitted,
        "line {line_no}: more completions than admissions"
    );
    assert!(
        admitted + rejected <= submitted,
        "line {line_no}: admitted + rejected exceeds submitted"
    );

    // Time accounting + latency histogram + throughput (all versions).
    for key in [
        "virtual_steps",
        "steps",
        "idle_steps",
        "fast_forwarded",
        "p50_step_us",
        "p99_step_us",
        "mean_step_us",
        "batched_rows",
        "rows_per_sec",
        "macs_per_sec",
        "elapsed_sec",
        "gc_evictions",
        "live_patterns_after_gc",
    ] {
        counter(line_no, line, key);
    }

    // Sub-objects (all versions).
    let cache = field(line_no, line, "cache");
    for key in ["hits", "misses", "evictions"] {
        counter(line_no, cache, key);
    }
    let epoch = field(line_no, line, "epoch");
    for key in ["hits", "misses", "unchanged", "hit_rate"] {
        counter(line_no, epoch, key);
    }
    let regen = field(line_no, line, "regen");
    for key in ["regenerated", "reused", "full_rebuilds", "reuse_rate"] {
        counter(line_no, regen, key);
    }

    // Schema 3: byte accounting.
    if schema >= 3 {
        counter(line_no, cache, "bytes_resident");
        counter(line_no, cache, "bytes_evicted");
        for key in [
            "max_pattern_bytes",
            "band_rows",
            "peak_pattern_bytes",
            "pattern_bytes_resident",
            "pattern_bytes_evicted",
            "band_compiles",
            "gc_bytes_reclaimed",
        ] {
            counter(line_no, line, key);
        }
    }

    // Schema 4: exactness contract.
    if schema >= 4 {
        assert!(
            !str_field(line_no, line, "backend").is_empty(),
            "line {line_no}: empty backend name"
        );
        let exactness = str_field(line_no, line, "exactness");
        assert!(
            exactness == "bitwise" || (exactness.starts_with("ulps(") && exactness.ends_with(')')),
            "line {line_no}: exactness {exactness:?} is neither \"bitwise\" nor \"ulps(k)\""
        );
    }

    // Schema 5: multi-process coordination.
    if schema >= 5 {
        let worker_procs = counter(line_no, line, "worker_procs");
        let digest = str_field(line_no, line, "output_digest");
        assert!(
            digest.len() == 16 && digest.bytes().all(|b| b.is_ascii_hexdigit()),
            "line {line_no}: output_digest {digest:?} is not 16 hex digits"
        );
        let coord = line.get("coord");
        assert_eq!(
            coord.is_some(),
            worker_procs > 0.0,
            "line {line_no}: `coord` must be present iff worker_procs > 0"
        );
        if let Some(coord) = coord {
            for key in [
                "joins",
                "rejoins",
                "crashes",
                "rejected_stale_epoch",
                "rejected_duplicate",
                "nacks",
                "spec_installs",
                "delta_broadcasts",
                "evict_broadcasts",
            ] {
                counter(line_no, coord, key);
            }
            let grants = counter(line_no, coord, "grants");
            let accepted = counter(line_no, coord, "accepted");
            let superseded = counter(line_no, coord, "superseded");
            let voided = counter(line_no, coord, "voided");
            let regrants = counter(line_no, coord, "regrants");
            assert_eq!(
                accepted + superseded + voided,
                grants,
                "line {line_no}: coord ledger does not conserve"
            );
            assert!(
                regrants <= superseded + voided,
                "line {line_no}: regrants exceed superseded + voided"
            );
            counter(line_no, coord, "worker_rows");
            counter(line_no, coord, "inline_rows");
        }
    }

    // Schema 6: content-based spec families + load-balance observables.
    if schema >= 6 {
        let family = str_field(line_no, line, "spec_family");
        assert!(
            ["routing", "expert-choice", "threshold"].contains(&family),
            "line {line_no}: spec_family {family:?} is not a `--spec` value"
        );
        counter(line_no, line, "max_cluster_nnz");
        let max_shard = counter(line_no, line, "max_shard_nnz");
        let min_shard = counter(line_no, line, "min_shard_nnz");
        assert!(
            min_shard <= max_shard,
            "line {line_no}: min_shard_nnz {min_shard} exceeds max_shard_nnz {max_shard}"
        );
    }
}

/// Every line of the trajectory parses and satisfies its declared schema.
#[test]
fn every_trajectory_line_matches_its_declared_schema() {
    let mut lines = 0usize;
    for (idx, raw) in TRAJECTORY.lines().enumerate() {
        let line_no = idx + 1;
        if raw.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(raw)
            .unwrap_or_else(|e| panic!("line {line_no}: invalid JSON: {e:?}"));
        check_line(line_no, &parsed);
        lines += 1;
    }
    assert!(
        lines >= 1,
        "BENCH_serve.json must keep its seed line — a 0-byte trajectory \
         makes every per-line consumer vacuously green"
    );
}

/// The seed line (line 1) is current-schema so a fresh checkout's
/// trajectory already exercises the newest field contract, including
/// the digest anchor the coordinated-serve CI smoke compares against.
#[test]
fn seed_line_is_current_schema_with_zeroed_metrics() {
    let raw = TRAJECTORY
        .lines()
        .find(|l| !l.trim().is_empty())
        .expect("trajectory has a first line");
    let line = Json::parse(raw).expect("seed line parses");
    assert_eq!(
        field(1, &line, "schema").as_i64(),
        Some(MAX_SCHEMA),
        "seed line must declare the current schema"
    );
    assert_eq!(num(1, &line, "requests"), 0.0, "seed line is a zero-run");
    assert_eq!(num(1, &line, "batched_rows"), 0.0);
    assert_eq!(num(1, &line, "worker_procs"), 0.0);
    assert_eq!(
        str_field(1, &line, "output_digest"),
        "0000000000000000",
        "the hand-written seed line uses the all-zero digest sentinel"
    );
}
